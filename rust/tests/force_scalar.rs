//! EFLA_FORCE_SCALAR round-trip: setting the env var before the first
//! dispatch must pin the whole matmul family to the scalar tier.
//!
//! Deliberately a single #[test] in its own binary: the dispatcher caches
//! the env read on first use, so the variable is set before any dispatched
//! call in this process, with no sibling tests racing the cache.

#![forbid(unsafe_code)]

use efla::tensor::{
    active_kernel, axpy, dot, gemm, matmul_into, matmul_nt_into, matmul_tn_into, Kernel,
    ENV_FORCE_SCALAR,
};
use efla::util::rng::Rng;

#[test]
fn env_override_round_trips_through_the_dispatcher() {
    std::env::set_var(ENV_FORCE_SCALAR, "1");
    assert_eq!(
        active_kernel(),
        Kernel::Scalar,
        "{ENV_FORCE_SCALAR}=1 must resolve the dispatcher to the scalar tier"
    );

    // With the scalar tier forced, dispatched calls are the scalar calls —
    // bit for bit, not just within tolerance.
    let mut rng = Rng::new(9001);
    for &(m, k, n) in &[(5usize, 8usize, 16usize), (61, 67, 33), (128, 256, 64)] {
        let a = rng.normal_vec(m * k, 0.0, 1.0);
        let b = rng.normal_vec(k * n, 0.0, 1.0);
        let mut c_ref = vec![0.0f32; m * n];
        gemm::scalar::matmul_into(&a, &b, &mut c_ref, m, k, n);
        let mut c = vec![0.0f32; m * n];
        matmul_into(&a, &b, &mut c, m, k, n);
        assert_eq!(c_ref, c, "nn {m}x{k}x{n} must be bit-identical under force-scalar");

        let bt = rng.normal_vec(n * k, 0.0, 1.0);
        let mut c_ref = vec![0.0f32; m * n];
        gemm::scalar::matmul_nt_into(&a, &bt, &mut c_ref, m, k, n);
        let mut c = vec![0.0f32; m * n];
        matmul_nt_into(&a, &bt, &mut c, m, k, n);
        assert_eq!(c_ref, c, "nt {m}x{k}x{n}");

        let bm = rng.normal_vec(m * n, 0.0, 1.0);
        let mut c_ref = vec![0.0f32; k * n];
        gemm::scalar::matmul_tn_into(&a, &bm, &mut c_ref, m, k, n);
        let mut c = vec![0.0f32; k * n];
        matmul_tn_into(&a, &bm, &mut c, m, k, n);
        assert_eq!(c_ref, c, "tn {m}x{k}x{n}");

        let x = rng.normal_vec(k, 0.0, 1.0);
        let y = rng.normal_vec(k, 0.0, 1.0);
        assert_eq!(dot(&x, &y).to_bits(), gemm::scalar::dot(&x, &y).to_bits());
        let mut y1 = y.clone();
        axpy(0.5, &x, &mut y1);
        let mut y2 = y.clone();
        gemm::scalar::axpy(0.5, &x, &mut y2);
        assert_eq!(y1, y2);
    }
}
