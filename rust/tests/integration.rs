//! Integration tests over the real PJRT runtime + tiny AOT artifacts.
//!
//! Compiled only with `--features xla` (the PJRT backend needs a vendored
//! `xla` crate) and need `make artifacts` to have run (artifacts/ +
//! manifest.json). Each test opens its own Runtime (PJRT CPU clients are
//! cheap) and uses the tiny preset so the whole file runs in seconds.
//!
//! Backend-agnostic coverage (CPU backend) lives in `tests/cpu_backend.rs`.

#![forbid(unsafe_code)]
#![cfg(feature = "xla")]

use std::path::{Path, PathBuf};

use efla::attention::{chunkwise_delta, Gate};
use efla::coordinator::schedule::Schedule;
use efla::coordinator::server::{GenRequest, Server};
use efla::coordinator::session::Session;
use efla::coordinator::trainer;
use efla::data::loader::TokenStream;
use efla::runtime::{HostValue, Runtime};
use efla::tensor::Tensor;
use efla::util::json;
use efla::util::rng::Rng;

fn artifact_dir() -> PathBuf {
    let candidates = [
        PathBuf::from("artifacts"),
        PathBuf::from(env!("CARGO_MANIFEST_DIR")).join("artifacts"),
    ];
    for c in candidates {
        if c.join("manifest.json").exists() {
            return c;
        }
    }
    panic!("artifacts/manifest.json not found — run `make artifacts` first");
}

fn runtime() -> Runtime {
    Runtime::open(&artifact_dir()).expect("open runtime")
}

fn lm_batch(seed: u64, batch: usize, seq: usize, vocab: i32) -> (HostValue, HostValue) {
    let mut rng = Rng::new(seed);
    let ids: Vec<i32> = (0..batch * seq * 2).map(|_| rng.below(vocab as u64) as i32).collect();
    let mut stream = TokenStream::new(ids);
    let (t, y) = stream.lm_batch(batch, seq);
    (
        HostValue::i32(&[batch, seq], t),
        HostValue::i32(&[batch, seq], y),
    )
}

#[test]
fn manifest_lists_tiny_family() {
    let rt = runtime();
    for graph in ["init", "step", "eval", "logits_last", "decode", "prefill"] {
        assert!(
            rt.has(&format!("lm_tiny_efla_{graph}")),
            "missing artifact lm_tiny_efla_{graph}"
        );
    }
}

#[test]
fn init_is_deterministic_in_seed() {
    let rt = runtime();
    let s1 = Session::init(&rt, "lm_tiny_efla", 7).unwrap();
    let s2 = Session::init(&rt, "lm_tiny_efla", 7).unwrap();
    let s3 = Session::init(&rt, "lm_tiny_efla", 8).unwrap();
    let (p1, p2, p3) = (
        s1.export_params().unwrap(),
        s2.export_params().unwrap(),
        s3.export_params().unwrap(),
    );
    for (a, b) in p1.iter().zip(p2.iter()) {
        assert_eq!(a, b, "same seed must give identical params");
    }
    let any_diff = p1
        .iter()
        .zip(p3.iter())
        .any(|(a, b)| a.shape() == b.shape() && a.max_abs_diff(b) > 1e-6);
    assert!(any_diff, "different seeds must give different params");
}

#[test]
fn training_reduces_loss_on_fixed_batch() {
    let rt = runtime();
    let mut session = Session::init(&rt, "lm_tiny_efla", 42).unwrap();
    let (t, y) = lm_batch(1, session.batch, session.seq, 256);
    let mut first = None;
    let mut last = 0.0;
    for _ in 0..30 {
        let m = session.step([t.clone(), y.clone()], 1e-3).unwrap();
        first.get_or_insert(m.loss);
        last = m.loss;
        assert!(m.loss.is_finite(), "loss must stay finite");
        assert!(m.grad_norm.is_finite());
    }
    let first = first.unwrap();
    assert!(
        last < first - 0.5,
        "overfitting a fixed batch must drop loss: {first} -> {last}"
    );
}

#[test]
fn deltanet_variant_also_trains() {
    let rt = runtime();
    let mut session = Session::init(&rt, "lm_tiny_deltanet", 42).unwrap();
    let (t, y) = lm_batch(2, session.batch, session.seq, 256);
    let mut losses = Vec::new();
    for _ in 0..10 {
        let m = session.step([t.clone(), y.clone()], 1e-3).unwrap();
        losses.push(m.loss);
    }
    assert!(losses.last().unwrap() < losses.first().unwrap());
}

#[test]
fn eval_returns_consistent_statistics() {
    let rt = runtime();
    let session = Session::init(&rt, "lm_tiny_efla", 3).unwrap();
    let (t, y) = lm_batch(5, session.batch, session.seq, 256);
    let outs = session.eval([t, y]).unwrap();
    assert_eq!(outs.len(), 3);
    let (loss_sum, count, correct) = (outs[0], outs[1], outs[2]);
    // tiny: batch 4 x seq 64, last target per row = valid (stream targets)
    assert!(count > 0.0 && count <= (session.batch * session.seq) as f32);
    assert!(loss_sum > 0.0);
    assert!(correct >= 0.0 && correct <= count);
    // untrained model on 256-way uniform data: mean loss near ln(256)
    let mean = loss_sum / count;
    assert!((mean - (256f32).ln()).abs() < 1.0, "mean loss {mean}");
}

#[test]
fn decode_state_advances_between_steps() {
    let rt = runtime();
    let session = Session::init(&rt, "lm_tiny_efla", 13).unwrap();
    let b = session.decode_batch().unwrap();
    let vocab = session.vocab().unwrap();
    assert!(b > 0 && vocab > 0);
    let mut state = session.decode_state().unwrap();
    let tokens = vec![65i32; b];
    let l1 = session.decode(&mut state, &tokens).unwrap();
    assert_eq!(l1.shape(), &[b, vocab]);
    assert!(l1.data().iter().all(|x| x.is_finite()));
    // feed the same token again with the advanced state: logits must differ
    let l2 = session.decode(&mut state, &tokens).unwrap();
    assert!(l1.max_abs_diff(&l2) > 1e-6, "state must advance");
}

#[test]
fn golden_vectors_pin_rust_reference_to_pallas_kernel() {
    let dir = artifact_dir();
    let golden = json::read_file(&dir.join("golden.json")).unwrap();
    let cw = golden.get("chunkwise");
    let shape = cw.get("shape").usize_array().unwrap();
    let (b, h, l, d) = (shape[0], shape[1], shape[2], shape[3]);
    assert_eq!(b, 1);
    let chunk = cw.get("chunk").as_usize().unwrap();
    let q = cw.get("q").f32_array().unwrap();
    let k = cw.get("k").f32_array().unwrap();
    let v = cw.get("v").f32_array().unwrap();
    let beta = cw.get("beta").f32_array().unwrap();
    let out = cw.get("out").f32_array().unwrap();
    let state = cw.get("state").f32_array().unwrap();

    for hh in 0..h {
        let slice = |x: &[f32]| {
            Tensor::from_vec(&[l, d], x[hh * l * d..(hh + 1) * l * d].to_vec())
        };
        let (o_rs, s_rs) = chunkwise_delta(
            Gate::Efla,
            &slice(&q),
            &slice(&k),
            &slice(&v),
            &beta[hh * l..(hh + 1) * l],
            chunk,
        );
        let o_py = slice(&out);
        let s_py = Tensor::from_vec(&[d, d], state[hh * d * d..(hh + 1) * d * d].to_vec());
        assert!(
            o_rs.max_abs_diff(&o_py) < 1e-4,
            "head {hh}: rust vs pallas out diff {}",
            o_rs.max_abs_diff(&o_py)
        );
        assert!(s_rs.max_abs_diff(&s_py) < 1e-4);
    }

    // Gate curves: rust alpha matches python alpha on the shared grid.
    let gates = golden.get("gates");
    let xs = gates.get("x").f64_array().unwrap();
    let efla = gates.get("efla").f32_array().unwrap();
    for (i, &x) in xs.iter().enumerate() {
        let a = efla::attention::alpha_efla(x as f32, 1.0);
        assert!((a - efla[i]).abs() < 1e-5, "x={x}: {a} vs {}", efla[i]);
    }
    for order in [1u32, 2, 4] {
        let py = gates.get(&format!("rk{order}")).f32_array().unwrap();
        for (i, &x) in xs.iter().enumerate() {
            let a = efla::attention::alpha_rk(x as f32, 1.0, order);
            assert!((a - py[i]).abs() < 2e-4 * (1.0 + py[i].abs()), "rk{order} x={x}");
        }
    }
}

#[test]
fn trainer_run_end_to_end_with_checkpoint() {
    let rt = runtime();
    let out = std::env::temp_dir().join(format!("efla_it_{}", std::process::id()));
    let cfg = efla::coordinator::config::RunConfig {
        steps: 8,
        eval_batches: 2,
        corpus_bytes: 100_000,
        out_dir: out.clone(),
        ..Default::default()
    };
    let hist = trainer::run(&rt, &cfg).unwrap();
    assert_eq!(hist.curve.len(), 8);
    assert!(hist.final_loss().is_finite());
    assert_eq!(hist.evals.len(), 1);
    let ckpt = out.join("lm_tiny_efla").join("final.ckpt");
    assert!(ckpt.exists());
    let (step, tensors) = efla::coordinator::checkpoint::load(&ckpt).unwrap();
    assert_eq!(step, 8);
    // restore into a fresh session and take one more step
    let mut s2 = Session::init(&rt, "lm_tiny_efla", 1).unwrap();
    s2.import_state(&tensors, step).unwrap();
    let (t, y) = lm_batch(33, s2.batch, s2.seq, 256);
    let m = s2.step([t, y], 1e-4).unwrap();
    assert!(m.loss.is_finite());
    assert_eq!(s2.steps_done(), 9);
    std::fs::remove_dir_all(&out).ok();
}

#[test]
fn server_completes_batched_requests() {
    let rt = runtime();
    let session = Session::init(&rt, "lm_tiny_efla", 5).unwrap();
    let mut server = Server::new(&session, 99).unwrap();
    let mut rng = Rng::new(1);
    for id in 0..6u64 {
        // more requests than slots (batch=4): exercises continuous batching
        let prompt: Vec<i32> = (0..rng.range(3, 10)).map(|_| rng.below(256) as i32).collect();
        let req = GenRequest {
            id,
            prompt,
            max_new: 5,
            temperature: 0.0,
            deadline: None,
            session_id: None,
        };
        server.submit(req).unwrap();
    }
    let results = server.run_to_completion().unwrap();
    assert_eq!(results.len(), 6);
    for r in &results {
        assert_eq!(r.tokens.len(), 5);
        assert!(r.tokens.iter().all(|&t| (0..256).contains(&t)));
    }
    assert!(server.stats.engine_steps > 0);
    assert_eq!(server.stats.completed, 6);
}

#[test]
fn classifier_artifacts_train_when_present() {
    let rt = runtime();
    if !rt.has("clf_efla_step") {
        eprintln!("skipping: classifier artifacts not built (core set)");
        return;
    }
    let mut session = Session::init(&rt, "clf_efla", 42).unwrap();
    let pf = trainer::clf_data(session.batch, 1, efla::data::mnist::Corruption::None);
    let hist = trainer::train_lm(
        &mut session,
        Schedule::Constant { lr: 1e-3 },
        5,
        || pf.next(),
        |_| {},
    )
    .unwrap();
    assert!(hist.final_loss().is_finite());
}

#[test]
fn manifest_missing_artifact_errors_cleanly() {
    let rt = runtime();
    let err = match rt.load("lm_nonexistent_step") {
        Ok(_) => panic!("loading a missing artifact must fail"),
        Err(e) => e,
    };
    assert!(format!("{err}").contains("not in manifest"));
}

#[test]
fn mismatched_input_shape_rejected_before_execution() {
    let rt = runtime();
    let exe = rt.load("lm_tiny_efla_eval").unwrap();
    let bad = vec![HostValue::scalar_f32(0.0); exe.spec().inputs.len()];
    let err = exe.run(&bad).unwrap_err();
    assert!(format!("{err}").contains("expects"));
}

#[test]
fn hlo_artifacts_exist_and_are_text() {
    let dir = artifact_dir();
    for name in ["lm_tiny_efla_step", "lm_tiny_deltanet_init"] {
        let p: &Path = &dir.join(format!("{name}.hlo.txt"));
        let head = std::fs::read_to_string(p).unwrap();
        assert!(head.starts_with("HloModule"), "{name} must be HLO text");
    }
}
