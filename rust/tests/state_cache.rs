//! Session state cache: mechanics and the bit-identity contract.
//!
//! The contract under test is the one every serving PR pins: a turn that
//! resumes from a parked recurrent state generates **bit-identical**
//! tokens to replaying the full conversation transcript through prefill —
//! into any slot, through the disk spill tier, and after evictions (which
//! merely fall back to a cold full prefill). These tests run under all
//! three CI matrix legs (default, `EFLA_NUM_THREADS=1`,
//! `EFLA_FORCE_SCALAR=1`), so the identity holds per thread count and
//! matmul tier.

#![forbid(unsafe_code)]

use efla::coordinator::server::{GenRequest, Server, ServerConfig};
use efla::coordinator::session::Session;
use efla::runtime::CpuBackend;
use efla::serve::state_cache::{CachedState, StateCache};
use efla::util::rng::Rng;

fn tiny_session() -> Session {
    let backend = CpuBackend::new();
    Session::init(&backend, "lm_tiny_efla", 5).unwrap()
}

fn req(id: u64, prompt: Vec<i32>, max_new: usize, session: Option<&str>) -> GenRequest {
    GenRequest {
        id,
        prompt,
        max_new,
        temperature: 0.0,
        deadline: None,
        session_id: session.map(String::from),
    }
}

fn cached_cfg(bytes: usize, dir: &str) -> ServerConfig {
    ServerConfig {
        state_cache_bytes: bytes,
        state_cache_dir: dir.to_string(),
        ..ServerConfig::default()
    }
}

fn rand_prompt(rng: &mut Rng, len: usize, vocab: u64) -> Vec<i32> {
    (0..len).map(|_| rng.below(vocab) as i32).collect()
}

/// Run one greedy request alone and return its generated tokens.
fn run_one(server: &mut Server<'_>, r: GenRequest) -> Vec<i32> {
    let id = r.id;
    server.submit(r).unwrap();
    let results = server.run_to_completion().unwrap();
    results.into_iter().find(|r| r.id == id).unwrap().tokens
}

fn state_bits(rows: &[Vec<f32>]) -> Vec<Vec<u32>> {
    rows.iter().map(|r| r.iter().map(|x| x.to_bits()).collect()).collect()
}

#[test]
fn exported_slot_state_imports_into_any_slot_bit_identically() {
    let session = tiny_session();
    assert!(session.supports_state_io());
    let b = session.decode_batch().unwrap();
    assert!(b >= 2, "test needs at least two slots");
    let vocab = session.vocab().unwrap() as u64;
    let mut rng = Rng::new(17);
    let toks = rand_prompt(&mut rng, 37, vocab);

    let mut state = session.decode_state().unwrap();
    session.prefill(&mut state, 0, &toks).unwrap();
    let rows = session.export_slot_state(&state, 0).unwrap();

    // Import into the LAST slot of a fresh zeroed state: the exported
    // rows must come back bit-for-bit, and untouched slots stay zero.
    let mut other = session.decode_state().unwrap();
    session.import_slot_state(&mut other, b - 1, &rows).unwrap();
    let back = session.export_slot_state(&other, b - 1).unwrap();
    assert_eq!(state_bits(&rows), state_bits(&back));
    let slot0 = session.export_slot_state(&other, 0).unwrap();
    assert!(slot0.iter().all(|r| r.iter().all(|&x| x == 0.0)), "import must not touch slot 0");

    // The imported state decodes bit-identically to the original slot:
    // feed the same next token everywhere, compare the two slots' logits.
    let next = vec![toks[0]; b];
    let l_orig = session.decode(&mut state, &next).unwrap();
    let l_import = session.decode(&mut other, &next).unwrap();
    let v = l_orig.len() / b;
    let row_orig: Vec<u32> = l_orig.data()[..v].iter().map(|x| x.to_bits()).collect();
    let row_import: Vec<u32> =
        l_import.data()[(b - 1) * v..].iter().map(|x| x.to_bits()).collect();
    assert_eq!(row_orig, row_import, "restored slot must decode bit-identically");
}

#[test]
fn cached_resume_matches_full_replay_in_a_different_slot() {
    let session = tiny_session();
    let vocab = session.vocab().unwrap() as u64;
    let mut rng = Rng::new(42);
    let t1 = rand_prompt(&mut rng, 40, vocab);
    let extra = rand_prompt(&mut rng, 9, vocab);

    let mut server = Server::with_config(&session, 9, cached_cfg(1 << 20, "")).unwrap();
    let gen1 = run_one(&mut server, req(1, t1.clone(), 6, Some("s")));
    assert_eq!(server.stats.cache_entries, 1, "turn 1 parked its state");
    assert_eq!(server.stats.cache_misses, 1, "turn 1 looked up an empty cache");

    // Turn 2 prompt = full transcript + the user's next message.
    let mut t2 = t1;
    t2.extend_from_slice(&gen1);
    t2.extend_from_slice(&extra);

    // A filler request is queued ahead of turn 2, so admit seats the
    // filler in slot 0 and turn 2 restores into slot 1 — a different
    // slot than the one its state was snapshotted from.
    server.submit(req(2, vec![5; 30], 6, None)).unwrap();
    server.submit(req(3, t2.clone(), 6, Some("s"))).unwrap();
    let results = server.run_to_completion().unwrap();
    let turn2 = results.into_iter().find(|r| r.id == 3).unwrap().tokens;
    assert_eq!(server.stats.cache_hits, 1, "turn 2 restored from the cache");

    // Reference: cold full replay of the same transcript, cache disabled.
    let mut cold = Server::with_config(&session, 9, ServerConfig::default()).unwrap();
    let replay = run_one(&mut cold, req(1, t2, 6, None));
    assert_eq!(turn2, replay, "cached resume must be bit-identical to full replay");
    assert_eq!(cold.stats.cache_hits, 0);
    assert_eq!(cold.stats.cache_misses, 0, "disabled cache never counts");
}

#[test]
fn concurrent_same_session_turns_are_serialized_without_tearing() {
    let session = tiny_session();
    let vocab = session.vocab().unwrap() as u64;
    let mut rng = Rng::new(7);
    let t1 = rand_prompt(&mut rng, 24, vocab);

    // Reference conversation without any caching.
    let mut reference = Server::new(&session, 1).unwrap();
    let gen1 = run_one(&mut reference, req(1, t1.clone(), 5, None));
    let mut t2 = t1.clone();
    t2.extend_from_slice(&gen1);
    t2.extend_from_slice(&[3, 1, 4]);
    let gen2 = run_one(&mut reference, req(2, t2.clone(), 5, None));

    // Both turns of one session submitted before any engine step. Turn 2
    // must stay queued while turn 1 holds a slot (its snapshot only
    // exists at finish), then restore and generate identical tokens.
    let mut server = Server::with_config(&session, 2, cached_cfg(1 << 20, "")).unwrap();
    server.submit(req(10, t1, 5, Some("conv"))).unwrap();
    server.submit(req(11, t2, 5, Some("conv"))).unwrap();
    let mut done = Vec::new();
    let mut saw_turn1_in_flight = false;
    while server.has_work() {
        if server.occupied_slots() > 0 && done.is_empty() {
            // While turn 1 runs, turn 2 must not share the batch.
            assert_eq!(server.occupied_slots(), 1, "same-session turns must not run together");
            assert_eq!(server.queue_len(), 1);
            saw_turn1_in_flight = true;
        }
        server.engine_step().unwrap();
        done.extend(server.take_results());
    }
    done.extend(server.take_results());
    assert!(saw_turn1_in_flight);
    done.sort_by_key(|r| r.id);
    assert_eq!(done.len(), 2);
    assert_eq!(done[0].tokens, gen1);
    assert_eq!(done[1].tokens, gen2, "serialized turn 2 must match the replay reference");
    assert_eq!(server.stats.cache_hits, 1);
}

#[test]
fn evicted_session_falls_back_to_cold_prefill() {
    let session = tiny_session();
    let vocab = session.vocab().unwrap() as u64;
    let mut rng = Rng::new(23);
    let t1 = rand_prompt(&mut rng, 20, vocab);

    let mut reference = Server::new(&session, 1).unwrap();
    let gen1 = run_one(&mut reference, req(1, t1.clone(), 4, None));
    let mut t2 = t1.clone();
    t2.extend_from_slice(&gen1);
    t2.extend_from_slice(&[9, 9]);
    let gen2 = run_one(&mut reference, req(2, t2.clone(), 4, None));

    // A 1-byte bound evicts every snapshot immediately (no spill dir →
    // dropped), so every turn runs cold — and still matches the replay.
    let mut server = Server::with_config(&session, 4, cached_cfg(1, "")).unwrap();
    assert_eq!(run_one(&mut server, req(10, t1, 4, Some("s"))), gen1);
    assert_eq!(run_one(&mut server, req(11, t2, 4, Some("s"))), gen2);
    assert_eq!(server.stats.cache_hits, 0);
    assert_eq!(server.stats.cache_misses, 2);
    assert_eq!(server.stats.cache_evictions, 2);
    assert_eq!(server.stats.cache_entries, 0);
}

#[test]
fn disk_spill_tier_restores_bit_identically() {
    let session = tiny_session();
    let vocab = session.vocab().unwrap() as u64;
    let mut rng = Rng::new(31);
    let t1 = rand_prompt(&mut rng, 28, vocab);

    let mut reference = Server::new(&session, 1).unwrap();
    let gen1 = run_one(&mut reference, req(1, t1.clone(), 4, None));
    let mut t2 = t1.clone();
    t2.extend_from_slice(&gen1);
    t2.extend_from_slice(&[7]);
    let gen2 = run_one(&mut reference, req(2, t2.clone(), 4, None));

    // 1-byte memory tier + a spill dir: every snapshot goes straight to
    // disk, and the follow-up turn restores from the disk tier.
    let dir = std::env::temp_dir().join(format!("efla_spill_{}", std::process::id()));
    std::fs::create_dir_all(&dir).unwrap();
    let mut server =
        Server::with_config(&session, 4, cached_cfg(1, dir.to_str().unwrap())).unwrap();
    assert_eq!(run_one(&mut server, req(10, t1, 4, Some("s"))), gen1);
    assert_eq!(run_one(&mut server, req(11, t2, 4, Some("s"))), gen2);
    assert_eq!(server.stats.cache_hits, 1);
    assert_eq!(server.stats.cache_disk_hits, 1, "the hit came from the disk tier");
    assert_eq!(server.stats.cache_spills, 2, "both snapshots were spilled");
    std::fs::remove_dir_all(&dir).ok();
}

#[test]
fn lru_eviction_and_spill_round_trip_via_cache_api() {
    // Direct API check of the bookkeeping the server tests exercise
    // end-to-end: byte-bounded LRU order and a lossless spill.
    let dir = std::env::temp_dir().join(format!("efla_lru_{}", std::process::id()));
    std::fs::create_dir_all(&dir).unwrap();
    let mut cache = StateCache::new(600, dir.to_str().unwrap());
    let entry = |tok: i32| CachedState {
        transcript: vec![tok; 4],
        rows: vec![vec![tok as f32 + 0.125; 64]],
    };
    cache.insert("a", entry(1));
    cache.insert("b", entry(2));
    // "c" pushes the cache over 600 bytes; "a" is least recently used
    // and must be the one spilled to disk.
    cache.insert("c", entry(3));
    let s = cache.stats();
    assert_eq!((s.entries, s.evictions, s.spills), (2, 1, 1));
    let back = cache.take("a", &[1, 1, 1, 1, 99]).expect("disk hit");
    assert_eq!(back, entry(1), "spill round-trip must be lossless");
    let s = cache.stats();
    assert_eq!((s.hits, s.disk_hits), (1, 1));
    assert!(cache.take("b", &[2, 2, 2, 2, 99]).is_some(), "b stayed resident");
    assert!(cache.take("c", &[3, 3, 3, 3, 99]).is_some(), "c stayed resident");
    std::fs::remove_dir_all(&dir).ok();
}
