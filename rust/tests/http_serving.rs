//! End-to-end tests of the HTTP serving front end.
//!
//! The load-bearing one is determinism: greedy generations served over
//! the network through the continuous-batching engine must be
//! **bit-identical** to the in-process `Server::run_to_completion` path
//! for the same session and request set — the HTTP layer and the
//! mid-flight slot churn may never change a token. CI runs this file in
//! all three matrix legs (default, `EFLA_NUM_THREADS=1`,
//! `EFLA_FORCE_SCALAR=1`), so the equivalence is pinned per kernel tier
//! and per thread count.
//!
//! With the slot-batched decode path the same contract holds along the
//! occupancy axis: a greedy request's tokens may not depend on which
//! other requests share its decode steps, pinned below by serving the
//! same request alone and among staggered neighbors.
//!
//! The rest covers the service behaviors: 429 backpressure under queue
//! overflow, graceful drain on shutdown, duplicate-id conflict, the
//! stats/health endpoints, and request validation.

#![forbid(unsafe_code)]

use std::io::{BufRead, BufReader, Write};
use std::net::TcpStream;
use std::sync::atomic::Ordering;

use efla::coordinator::server::{GenRequest, Server, ServerConfig, ServerStats};
use efla::coordinator::session::Session;
use efla::runtime::CpuBackend;
use efla::serve::{http, Frontend};
use efla::util::json;

fn tiny_session() -> Session {
    let backend = CpuBackend::new();
    Session::init(&backend, "lm_tiny_efla", 7).unwrap()
}

/// Run the front end on an OS port, hand the client closure the address,
/// then drain and return (client result, final engine stats).
fn with_server<F, T>(session: &Session, cfg: ServerConfig, f: F) -> (T, ServerStats)
where
    F: FnOnce(&str) -> T + Send,
    T: Send,
{
    let fe = Frontend::bind("127.0.0.1:0").unwrap();
    let addr = fe.local_addr().unwrap().to_string();
    let stop = fe.shutdown_flag();
    std::thread::scope(|s| {
        let client = s.spawn(move || {
            // Flip the flag even when a client assertion panics —
            // otherwise the engine would serve forever and hang the test.
            struct StopGuard(std::sync::Arc<std::sync::atomic::AtomicBool>);
            impl Drop for StopGuard {
                fn drop(&mut self) {
                    self.0.store(true, Ordering::SeqCst);
                }
            }
            let _guard = StopGuard(stop);
            f(&addr)
        });
        let stats = fe.run(session, cfg, 42).unwrap();
        (client.join().expect("client thread"), stats)
    })
}

fn generate_body(id: u64, prompt: &str, max_tokens: usize, stream: bool) -> String {
    format!("{{\"id\":{id},\"prompt\":{prompt:?},\"max_tokens\":{max_tokens},\"stream\":{stream}}}")
}

fn tokens_of(j: &json::Json) -> Vec<i32> {
    j.get("tokens").as_arr().unwrap().iter().map(|v| v.as_i64().unwrap() as i32).collect()
}

#[test]
fn http_path_matches_in_process_engine_bitwise() {
    let session = tiny_session();
    let prompts: Vec<String> =
        (0..6).map(|i| format!("request {i} of the determinism suite")).collect();
    let max_new = 4usize;

    // HTTP path: request 0 streamed, the rest plain; all greedy.
    let (http_tokens, stats) = with_server(&session, ServerConfig::default(), |addr| {
        let mut out: Vec<Vec<i32>> = Vec::new();
        for (i, p) in prompts.iter().enumerate() {
            let body = generate_body(i as u64 + 1, p, max_new, i == 0);
            let resp = http::request(addr, "POST", "/v1/generate", body.as_bytes()).unwrap();
            assert_eq!(resp.status, 200, "request {i}: {}", resp.text());
            let text = resp.text();
            let last = text.lines().last().expect("response body");
            let j = json::parse(last).unwrap();
            if i == 0 {
                // Streamed: one JSON line per token plus the final line,
                // whose token list must match the streamed pieces.
                let lines: Vec<&str> = text.lines().collect();
                assert_eq!(lines.len(), max_new + 1, "stream lines: {text}");
                let streamed: Vec<i32> = lines[..max_new]
                    .iter()
                    .map(|l| json::parse(l).unwrap().get("token").as_i64().unwrap() as i32)
                    .collect();
                assert_eq!(streamed, tokens_of(&j), "streamed pieces vs final result");
                assert_eq!(j.get("done").as_bool(), Some(true));
            }
            assert_eq!(j.get("id").as_i64(), Some(i as i64 + 1));
            out.push(tokens_of(&j));
        }
        out
    });
    assert_eq!(stats.completed, prompts.len() as u64);

    // In-process reference on the very same session (greedy decode is
    // RNG-free, so engine seeds and scheduling order cannot matter).
    let mut server = Server::new(&session, 99).unwrap();
    for (i, p) in prompts.iter().enumerate() {
        let prompt: Vec<i32> = p.bytes().map(|b| b as i32).collect();
        server
            .submit(GenRequest {
                id: i as u64,
                prompt,
                max_new,
                temperature: 0.0,
                deadline: None,
                session_id: None,
            })
            .unwrap();
    }
    let reference = server.run_to_completion().unwrap();
    assert_eq!(reference.len(), prompts.len());
    for (i, r) in reference.iter().enumerate() {
        assert_eq!(
            http_tokens[i], r.tokens,
            "request {i}: HTTP + continuous batching must be bit-identical to in-process"
        );
    }
}

#[test]
fn request_tokens_are_occupancy_invariant_over_http() {
    // The slot-batched decode contract observed end-to-end: a greedy
    // request must generate bit-identical tokens whether it runs alone
    // or shares every decode step with staggered neighbors.
    let session = tiny_session();
    let probe = "occupancy probe request";
    let max_new = 6usize;

    let (solo, _) = with_server(&session, ServerConfig::default(), |addr| {
        let body = generate_body(1, probe, max_new, false);
        let resp = http::request(addr, "POST", "/v1/generate", body.as_bytes()).unwrap();
        assert_eq!(resp.status, 200, "{}", resp.text());
        tokens_of(&json::parse(&resp.text()).unwrap())
    });

    // Seat long-running neighbors first, then send the probe, so its
    // decode steps ride in a partially-occupied slot block.
    let (shared, stats) = with_server(&session, ServerConfig::default(), |addr| {
        std::thread::scope(|s| {
            for i in 0..3u64 {
                s.spawn(move || {
                    let body = generate_body(i + 10, "neighbor padding request", 48, false);
                    let resp = http::request(addr, "POST", "/v1/generate", body.as_bytes())
                        .unwrap();
                    assert_eq!(resp.status, 200, "neighbor {i}: {}", resp.text());
                });
            }
            std::thread::sleep(std::time::Duration::from_millis(100));
            let body = generate_body(1, probe, max_new, false);
            let resp = http::request(addr, "POST", "/v1/generate", body.as_bytes()).unwrap();
            assert_eq!(resp.status, 200, "{}", resp.text());
            tokens_of(&json::parse(&resp.text()).unwrap())
        })
    });
    assert_eq!(stats.completed, 4, "probe + 3 neighbors all complete");
    assert_eq!(shared, solo, "greedy tokens must not depend on slot occupancy");
}

#[test]
fn queue_overflow_returns_429_and_service_recovers() {
    let session = tiny_session();
    let cfg = ServerConfig { queue_depth: 1, ..ServerConfig::default() };
    let (statuses, stats) = with_server(&session, cfg, |addr| {
        // 16 concurrent long generations against 4 slots + 1 queue slot:
        // the excess must bounce with 429 instead of stalling.
        let mut statuses: Vec<u16> = std::thread::scope(|s| {
            let handles: Vec<_> = (0..16u64)
                .map(|i| {
                    s.spawn(move || {
                        let body = generate_body(i + 1, "overflow probe", 96, false);
                        http::request(addr, "POST", "/v1/generate", body.as_bytes())
                            .unwrap()
                            .status
                    })
                })
                .collect();
            handles.into_iter().map(|h| h.join().expect("client")).collect()
        });
        // The service must keep serving once the burst drains.
        let mut recovered = 0u16;
        for _ in 0..100 {
            let body = generate_body(999, "recovery probe", 2, false);
            let resp = http::request(addr, "POST", "/v1/generate", body.as_bytes()).unwrap();
            recovered = resp.status;
            if recovered == 200 {
                break;
            }
            std::thread::sleep(std::time::Duration::from_millis(50));
        }
        assert_eq!(recovered, 200, "service must recover after the overflow burst");
        statuses.push(recovered);
        statuses
    });
    let ok = statuses.iter().filter(|&&s| s == 200).count();
    let busy = statuses.iter().filter(|&&s| s == 429).count();
    assert!(ok >= 1, "some requests must be served: {statuses:?}");
    assert!(busy >= 1, "queue_depth=1 under a 16-burst must bounce some: {statuses:?}");
    assert_eq!(ok + busy, statuses.len(), "only 200/429 expected: {statuses:?}");
    assert_eq!(stats.completed, ok as u64, "every accepted request completes");
}

#[test]
fn shutdown_drains_in_flight_requests() {
    let session = tiny_session();
    let cfg = ServerConfig { drain_timeout_secs: 60.0, ..ServerConfig::default() };
    let fe = Frontend::bind("127.0.0.1:0").unwrap();
    let addr = fe.local_addr().unwrap().to_string();
    let stop = fe.shutdown_flag();
    let max_new = 32usize;
    let (results, stats) = std::thread::scope(|s| {
        let client = s.spawn(move || {
            let results: Vec<(u16, usize)> = std::thread::scope(|inner| {
                let handles: Vec<_> = (0..4u64)
                    .map(|i| {
                        let addr = addr.as_str();
                        inner.spawn(move || {
                            let body = generate_body(i + 1, "drain probe", max_new, false);
                            let resp = http::request(addr, "POST", "/v1/generate", body.as_bytes())
                                .unwrap();
                            let ntok = match json::parse(&resp.text()) {
                                Ok(j) => tokens_of(&j).len(),
                                Err(_) => 0,
                            };
                            (resp.status, ntok)
                        })
                    })
                    .collect();
                // Flip the flag once the requests are surely submitted (and
                // likely still in flight): accepted work must finish, not
                // be cut off.
                std::thread::sleep(std::time::Duration::from_millis(150));
                stop.store(true, Ordering::SeqCst);
                handles.into_iter().map(|h| h.join().expect("client")).collect()
            });
            results
        });
        let stats = fe.run(&session, cfg, 42).unwrap();
        (client.join().expect("client thread"), stats)
    });
    for (i, (status, ntok)) in results.iter().enumerate() {
        assert_eq!(*status, 200, "request {i} must drain to completion");
        assert_eq!(*ntok, max_new, "request {i} must keep its full token budget");
    }
    assert_eq!(stats.completed, 4);
}

#[test]
fn duplicate_live_id_gets_409_over_http() {
    let session = tiny_session();
    // The held generation uses the server's full max_tokens budget so it
    // cannot finish (and free its id) before the duplicate arrives; the
    // short drain timeout keeps the end-of-test shutdown from replaying
    // the whole 4096-token budget.
    let cfg = ServerConfig { drain_timeout_secs: 0.5, ..ServerConfig::default() };
    let ((), _stats) = with_server(&session, cfg, |addr| {
        // Open a long streamed generation and read its first token chunk —
        // proof the id is seated and still generating.
        let body = generate_body(5, "hold this slot for a while", 4096, true);
        let mut a = TcpStream::connect(addr).unwrap();
        write!(
            a,
            "POST /v1/generate HTTP/1.1\r\nhost: t\r\ncontent-length: {}\r\n\
             connection: close\r\n\r\n",
            body.len()
        )
        .unwrap();
        a.write_all(body.as_bytes()).unwrap();
        a.flush().unwrap();
        let mut ar = BufReader::new(a);
        let mut line = String::new();
        ar.read_line(&mut line).unwrap();
        assert!(line.contains("200"), "stream head: {line:?}");
        loop {
            line.clear();
            ar.read_line(&mut line).unwrap();
            if line == "\r\n" || line == "\n" {
                break; // end of headers
            }
        }
        line.clear();
        ar.read_line(&mut line).unwrap(); // first chunk size
        assert!(!line.trim().is_empty(), "expected a first token chunk");

        // Same id while live: the typed DuplicateId maps to 409.
        let dup = generate_body(5, "duplicate", 2, false);
        let resp = http::request(addr, "POST", "/v1/generate", dup.as_bytes()).unwrap();
        assert_eq!(resp.status, 409, "{}", resp.text());
        assert!(resp.text().contains("already queued or in flight"), "{}", resp.text());
        // Dropping the streamed connection; the engine finishes the slot
        // on its own and the drain picks it up.
    });
}

#[test]
fn healthz_stats_and_routing() {
    let session = tiny_session();
    let ((), _stats) = with_server(&session, ServerConfig::default(), |addr| {
        let h = http::request(addr, "GET", "/healthz", b"").unwrap();
        assert_eq!(h.status, 200);
        let hj = json::parse(&h.text()).unwrap();
        assert_eq!(hj.get("ok").as_bool(), Some(true));
        assert!(hj.get("slots").as_usize().unwrap() >= 1);

        let body = generate_body(1, "stats probe", 3, false);
        let resp = http::request(addr, "POST", "/v1/generate", body.as_bytes()).unwrap();
        assert_eq!(resp.status, 200);

        let st = http::request(addr, "GET", "/stats", b"").unwrap();
        assert_eq!(st.status, 200);
        let sj = json::parse(&st.text()).unwrap();
        assert!(sj.get("accepted").as_f64().unwrap() >= 1.0);
        assert!(sj.get("slots").as_usize().unwrap() >= 1);
        for field in [
            "rejected",
            "queue_depth",
            "tokens_processed",
            "p95_e2e_ms",
            "p95_queue_wait_ms",
            "mean_ttft_ms",
            "utilization",
        ] {
            assert!(sj.get(field).as_f64().is_some(), "stats field {field}: {}", st.text());
        }

        let missing = http::request(addr, "GET", "/nope", b"").unwrap();
        assert_eq!(missing.status, 404);
        let wrong_method = http::request(addr, "GET", "/v1/generate", b"").unwrap();
        assert_eq!(wrong_method.status, 405);
    });
}

#[test]
fn request_deadline_expires_with_timeout_finish_reason() {
    // Satellite of the deadline plumbing: `timeout_ms` in the body turns
    // into an engine-side deadline — a stalled engine must hand the slot
    // back at the deadline with finish_reason "timeout" and the partial
    // tokens, and count the request in the `timed_out` stat.
    let session = tiny_session();
    let ((), stats) = with_server(&session, ServerConfig::default(), |addr| {
        let armed = http::request(addr, "POST", "/fault", b"engine_stall_ms=100").unwrap();
        assert_eq!(armed.status, 200, "{}", armed.text());
        let body = "{\"id\":1,\"prompt\":\"deadline probe\",\"max_tokens\":1000,\
                    \"timeout_ms\":250}";
        let resp = http::request(addr, "POST", "/v1/generate", body.as_bytes()).unwrap();
        assert_eq!(resp.status, 200, "{}", resp.text());
        let j = json::parse(&resp.text()).unwrap();
        assert_eq!(j.get("finish_reason").as_str(), Some("timeout"), "{}", resp.text());
        assert!(
            tokens_of(&j).len() < 1000,
            "the slot must be abandoned long before max_tokens: {}",
            resp.text()
        );
        let st = http::request(addr, "GET", "/stats", b"").unwrap();
        let sj = json::parse(&st.text()).unwrap();
        assert!(sj.get("timed_out").as_f64().unwrap() >= 1.0, "{}", st.text());
        // Disarm so the shutdown drain runs at full speed.
        let disarmed = http::request(addr, "POST", "/fault", b"").unwrap();
        assert_eq!(disarmed.status, 200);
    });
    assert!(stats.timed_out >= 1);
}

#[test]
fn healthz_reports_draining_and_streams_drain_to_completion() {
    // Two lifecycle contracts at once: during shutdown /healthz answers
    // 503 "draining" (so a router health check stops routing here), and
    // a streaming request that was in flight when the flag flipped keeps
    // its full token budget — no truncated chunked body.
    let session = tiny_session();
    let cfg = ServerConfig { drain_timeout_secs: 60.0, ..ServerConfig::default() };
    let fe = Frontend::bind("127.0.0.1:0").unwrap();
    let addr = fe.local_addr().unwrap().to_string();
    let stop = fe.shutdown_flag();
    let max_new = 24usize;
    let ((status, lines), stats) = std::thread::scope(|s| {
        let client = s.spawn(move || {
            // Slow the engine so the generation outlives the drain flip.
            let armed = http::request(&addr, "POST", "/fault", b"engine_stall_ms=30").unwrap();
            assert_eq!(armed.status, 200, "{}", armed.text());
            let streamer = s.spawn({
                let addr = addr.clone();
                move || {
                    let body = generate_body(1, "drain stream probe", max_new, true);
                    let resp =
                        http::request(&addr, "POST", "/v1/generate", body.as_bytes()).unwrap();
                    let lines: Vec<String> = resp.text().lines().map(String::from).collect();
                    (resp.status, lines)
                }
            });
            std::thread::sleep(std::time::Duration::from_millis(150));
            stop.store(true, Ordering::SeqCst);
            // The front end keeps serving probes through the drain and
            // reports itself as draining.
            let h = http::request(&addr, "GET", "/healthz", b"").unwrap();
            assert_eq!(h.status, 503, "{}", h.text());
            let hj = json::parse(&h.text()).unwrap();
            assert_eq!(hj.get("status").as_str(), Some("draining"));
            assert_eq!(hj.get("ok").as_bool(), Some(false));
            streamer.join().expect("streaming client")
        });
        let stats = fe.run(&session, cfg, 42).unwrap();
        (client.join().expect("client thread"), stats)
    });
    assert_eq!(status, 200);
    assert_eq!(lines.len(), max_new + 1, "token lines + final line: {lines:?}");
    let last = json::parse(lines.last().unwrap()).unwrap();
    assert_eq!(last.get("done").as_bool(), Some(true), "stream must terminate cleanly");
    assert_eq!(tokens_of(&last).len(), max_new, "drained stream keeps its budget");
    assert_eq!(last.get("finish_reason").as_str(), Some("length"));
    assert_eq!(stats.completed, 1);
}

#[test]
fn healthz_reports_saturated_while_the_queue_is_full() {
    let session = tiny_session();
    let cfg = ServerConfig { queue_depth: 1, ..ServerConfig::default() };
    let ((), _stats) = with_server(&session, cfg, |addr| {
        // Stall the engine, then bury it: slots + the 1-deep queue fill
        // up and stay full long enough to observe the saturated probe.
        let armed = http::request(addr, "POST", "/fault", b"engine_stall_ms=200").unwrap();
        assert_eq!(armed.status, 200, "{}", armed.text());
        std::thread::scope(|s| {
            for i in 0..8u64 {
                s.spawn(move || {
                    let body = generate_body(i + 1, "saturation probe", 2, false);
                    let resp =
                        http::request(addr, "POST", "/v1/generate", body.as_bytes()).unwrap();
                    assert!(
                        resp.status == 200 || resp.status == 429,
                        "request {i}: {}",
                        resp.text()
                    );
                });
            }
            let mut saw_saturated = false;
            for _ in 0..300 {
                let h = http::request(addr, "GET", "/healthz", b"").unwrap();
                let hj = json::parse(&h.text()).unwrap();
                if h.status == 503 && hj.get("status").as_str() == Some("saturated") {
                    assert_eq!(hj.get("ok").as_bool(), Some(false));
                    saw_saturated = true;
                    break;
                }
                std::thread::sleep(std::time::Duration::from_millis(10));
            }
            assert!(saw_saturated, "healthz never reported saturation under a full queue");
        });
        let disarmed = http::request(addr, "POST", "/fault", b"").unwrap();
        assert_eq!(disarmed.status, 200);
        // Once the burst drains the probe goes back to 200 "ok".
        for _ in 0..300 {
            let h = http::request(addr, "GET", "/healthz", b"").unwrap();
            if h.status == 200 {
                return;
            }
            std::thread::sleep(std::time::Duration::from_millis(10));
        }
        panic!("healthz never recovered to 200 after the queue drained");
    });
}

#[test]
fn generate_request_validation() {
    let session = tiny_session();
    let ((), _stats) = with_server(&session, ServerConfig::default(), |addr| {
        let cases: &[(&str, &str)] = &[
            ("not json at all", "invalid JSON"),
            ("{}", "'prompt'"),
            ("{\"prompt\":\"\"}", "empty prompt"),
            ("{\"prompt\":\"x\",\"max_tokens\":0}", "at least 1"),
            ("{\"tokens\":[1,\"two\"]}", "array of integers"),
            ("{\"prompt\":\"x\",\"id\":-3}", "non-negative"),
        ];
        for (body, needle) in cases {
            let resp = http::request(addr, "POST", "/v1/generate", body.as_bytes()).unwrap();
            assert_eq!(resp.status, 400, "{body}: {}", resp.text());
            assert!(resp.text().contains(needle), "{body}: {}", resp.text());
        }
    });
}
