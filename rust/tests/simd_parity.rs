//! SIMD/scalar parity suite over the public tensor API.
//!
//! Pins the dispatched matmul family (`matmul_into` / `matmul_nt_into` /
//! `matmul_tn_into`) plus `dot`/`axpy` against the portable scalar tier on
//! random rectangular shapes — full tiles, remainder rows/columns, and
//! depths that cross the packed kernel's KC blocking — at ≤ 1e-5 max abs
//! diff, and runs the chunkwise-vs-sequential golden comparison under
//! every explicitly forced tier the host supports.
//!
//! These tolerance-based comparisons hold whichever tier the dispatcher
//! resolves to, so the one test that flips the global `force_kernel` hook
//! cannot interfere with its siblings.

#![forbid(unsafe_code)]

use efla::attention::{chunkwise_delta, sequential_delta, Gate};
use efla::tensor::{axpy, dot, gemm, matmul_into, matmul_nt_into, matmul_tn_into, Kernel, Tensor};
use efla::util::rng::Rng;

/// Full tiles, remainder tiles (m % 6, n % 16), sub-cutoff shapes, and
/// k > 256 (crosses the packed KC block boundary).
const SIZES: &[(usize, usize, usize)] = &[
    (1, 4, 4),
    (2, 9, 3),
    (5, 8, 16),
    (6, 16, 16),
    (11, 31, 17),
    (23, 300, 19),
    (48, 64, 80),
    (61, 67, 129),
    (96, 256, 96),
];

fn max_abs_diff(a: &[f32], b: &[f32]) -> f32 {
    a.iter().zip(b.iter()).map(|(x, y)| (x - y).abs()).fold(0.0, f32::max)
}

#[test]
fn matmul_family_matches_scalar_tier() {
    let mut rng = Rng::new(7001);
    for &(m, k, n) in SIZES {
        let a = rng.normal_vec(m * k, 0.0, 0.05);
        let b = rng.normal_vec(k * n, 0.0, 0.05);
        let mut c_ref = vec![0.0f32; m * n];
        gemm::scalar::matmul_into(&a, &b, &mut c_ref, m, k, n);
        let mut c = vec![0.0f32; m * n];
        matmul_into(&a, &b, &mut c, m, k, n);
        assert!(max_abs_diff(&c_ref, &c) <= 1e-5, "nn {m}x{k}x{n}");

        let bt = rng.normal_vec(n * k, 0.0, 0.05);
        let mut c_ref = vec![0.0f32; m * n];
        gemm::scalar::matmul_nt_into(&a, &bt, &mut c_ref, m, k, n);
        let mut c = vec![0.0f32; m * n];
        matmul_nt_into(&a, &bt, &mut c, m, k, n);
        assert!(max_abs_diff(&c_ref, &c) <= 1e-5, "nt {m}x{k}x{n}");

        let bm = rng.normal_vec(m * n, 0.0, 0.05);
        let mut c_ref = vec![0.0f32; k * n];
        gemm::scalar::matmul_tn_into(&a, &bm, &mut c_ref, m, k, n);
        let mut c = vec![0.0f32; k * n];
        matmul_tn_into(&a, &bm, &mut c, m, k, n);
        assert!(max_abs_diff(&c_ref, &c) <= 1e-5, "tn {m}x{k}x{n}");
    }
}

#[test]
fn dot_axpy_match_scalar_tier() {
    let mut rng = Rng::new(7002);
    for len in [1usize, 5, 8, 13, 16, 25, 64, 127, 500] {
        let a = rng.normal_vec(len, 0.0, 0.05);
        let b = rng.normal_vec(len, 0.0, 0.05);
        assert!(
            (dot(&a, &b) - gemm::scalar::dot(&a, &b)).abs() <= 1e-5,
            "dot len {len}"
        );
        let mut y = b.clone();
        axpy(-1.3, &a, &mut y);
        let mut y_ref = b.clone();
        gemm::scalar::axpy(-1.3, &a, &mut y_ref);
        assert!(max_abs_diff(&y_ref, &y) <= 1e-5, "axpy len {len}");
    }
}

/// The chunkwise-vs-sequential golden comparison must hold at existing
/// tolerances under every tier — the arena-backed `_into` kernels and the
/// SIMD matmuls change rounding, never semantics.
#[test]
fn chunkwise_golden_holds_under_every_forced_tier() {
    for tier in [Kernel::Scalar, Kernel::Avx2Fma, Kernel::Avx512, Kernel::Neon] {
        let active = gemm::force_kernel(Some(tier));
        if active != tier {
            continue; // host lacks this tier: its leg is vacuous
        }
        let mut rng = Rng::new(7003);
        let (l, d) = (50, 16);
        let q = Tensor::from_vec(&[l, d], rng.normal_vec(l * d, 0.0, 1.0));
        let k = Tensor::from_vec(&[l, d], rng.normal_vec(l * d, 0.0, 0.7));
        let v = Tensor::from_vec(&[l, d], rng.normal_vec(l * d, 0.0, 1.0));
        let beta: Vec<f32> = (0..l).map(|_| rng.f32()).collect();
        let (o_seq, s_seq) = sequential_delta(Gate::Efla, &q, &k, &v, &beta);
        for chunk in [1usize, 8, 16, 64] {
            let (o_ch, s_ch) = chunkwise_delta(Gate::Efla, &q, &k, &v, &beta, chunk);
            let od = o_seq.max_abs_diff(&o_ch);
            let sd = s_seq.max_abs_diff(&s_ch);
            assert!(od < 2e-4, "{tier:?} C={chunk}: out diff {od}");
            assert!(sd < 2e-4, "{tier:?} C={chunk}: state diff {sd}");
        }
    }
    gemm::force_kernel(None); // restore host detection for sibling tests
}
