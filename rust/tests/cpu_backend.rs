//! Integration tests for the pure-Rust CPU execution backend — the
//! default, artifact-free path that tier-1 CI exercises.
//!
//! Covers the acceptance path `efla train --task lm --preset tiny
//! --mixer efla` end-to-end (data pipeline -> training -> eval ->
//! checkpoint), loss descent on a fixed batch, Backend/HostValue shape
//! round-trips, and the decode/serving path.

#![forbid(unsafe_code)]

use efla::coordinator::config::RunConfig;
use efla::coordinator::server::{GenRequest, Server};
use efla::coordinator::session::Session;
use efla::coordinator::trainer;
use efla::runtime::{open_backend, CpuBackend, HostValue};
use efla::util::rng::Rng;

fn fixed_lm_batch(session: &Session, seed: u64) -> (HostValue, HostValue) {
    let mut rng = Rng::new(seed);
    let rows = session.batch * session.seq;
    let vocab = session.vocab().expect("LM family has a vocab") as u64;
    let toks: Vec<i32> = (0..rows).map(|_| rng.below(vocab) as i32).collect();
    // next-token targets over the same stream: learnable structure
    let tgts: Vec<i32> = (0..rows)
        .map(|i| if (i + 1) % session.seq == 0 { -1 } else { toks[(i + 1) % rows] })
        .collect();
    (
        HostValue::i32(&[session.batch, session.seq], toks),
        HostValue::i32(&[session.batch, session.seq], tgts),
    )
}

#[test]
fn train_loss_is_finite_and_decreasing() {
    let backend = CpuBackend::new();
    let mut session = Session::init(&backend, "lm_tiny_efla", 42).unwrap();
    let (t, y) = fixed_lm_batch(&session, 1);
    let mut losses = Vec::new();
    for _ in 0..10 {
        let m = session.step([t.clone(), y.clone()], 3e-3).unwrap();
        assert!(m.loss.is_finite(), "loss must stay finite");
        assert!(m.grad_norm.is_finite() && m.grad_norm > 0.0);
        losses.push(m.loss);
    }
    let first = losses[0];
    let last = *losses.last().unwrap();
    assert!(
        last < first - 0.1,
        "overfitting a fixed batch must drop loss: {first} -> {last} ({losses:?})"
    );
}

#[test]
fn trainer_run_end_to_end_tiny_efla() {
    // The acceptance scenario: `efla train --task lm --preset tiny
    // --mixer efla` for a few steps on the CPU backend, through the full
    // pipeline (corpus -> BPE -> prefetcher -> train -> eval -> ckpt).
    let backend = open_backend(std::path::Path::new("artifacts-not-present")).unwrap();
    let out = std::env::temp_dir().join(format!("efla_cpu_it_{}", std::process::id()));
    let cfg = RunConfig {
        steps: 4,
        eval_batches: 1,
        corpus_bytes: 60_000,
        out_dir: out.clone(),
        ..Default::default()
    };
    let hist = trainer::run(backend.as_ref(), &cfg).unwrap();
    assert_eq!(hist.curve.len(), 4);
    for p in &hist.curve {
        assert!(p.loss.is_finite(), "loss must stay finite: {:?}", hist.curve);
    }
    assert_eq!(hist.evals.len(), 1);
    assert!(hist.evals[0].1.is_finite() && hist.evals[0].1 > 0.0, "ppl finite");

    // checkpoint restore round-trip
    let ckpt = out.join("lm_tiny_efla").join("final.ckpt");
    assert!(ckpt.exists());
    let (step, tensors) = efla::coordinator::checkpoint::load(&ckpt).unwrap();
    assert_eq!(step, 4);
    let mut s2 = Session::init(backend.as_ref(), "lm_tiny_efla", 1).unwrap();
    s2.import_state(&tensors, step).unwrap();
    let (t, y) = fixed_lm_batch(&s2, 33);
    let m = s2.step([t, y], 1e-4).unwrap();
    assert!(m.loss.is_finite());
    assert_eq!(s2.steps_done(), 5);
    std::fs::remove_dir_all(&out).ok();
}

#[test]
fn backend_roundtrips_hostvalue_shapes() {
    let backend = CpuBackend::new();
    let session = Session::init(&backend, "lm_tiny_efla", 7).unwrap();

    // Optimizer state round-trip: shapes and values survive export/import.
    let state = session.export_state().unwrap();
    assert_eq!(state.len(), 3 * session.n_params_tensors());
    let mut other = Session::init(&backend, "lm_tiny_efla", 8).unwrap();
    other.import_state(&state, 3).unwrap();
    assert_eq!(other.steps_done(), 3);
    let p1 = session.export_params().unwrap();
    let p2 = other.export_params().unwrap();
    for (a, b) in p1.iter().zip(p2.iter()) {
        assert_eq!(a.shape(), b.shape());
        assert!(a.max_abs_diff(b) == 0.0, "import must copy params exactly");
    }

    // Decode-state round-trip: every state tensor keeps its shape through
    // an in-place decode call, and logits have the advertised
    // (batch, vocab) shape.
    let b = session.decode_batch().unwrap();
    let vocab = session.vocab().unwrap();
    let mut state = session.decode_state().unwrap();
    let shapes: Vec<Vec<usize>> = state.iter().map(|hv| hv.shape().to_vec()).collect();
    for s in &shapes {
        assert_eq!(s[0], b, "state tensors are (decode_batch, ...) rows");
    }
    let tokens = vec![7i32; b];
    let logits = session.decode(&mut state, &tokens).unwrap();
    assert_eq!(logits.shape(), &[b, vocab]);
    for (hv, s) in state.iter().zip(shapes.iter()) {
        assert_eq!(hv.shape(), s.as_slice(), "decode must preserve state shapes");
    }
}

#[test]
fn open_backend_without_artifacts_is_cpu() {
    let backend = open_backend(std::path::Path::new("definitely-missing")).unwrap();
    assert!(backend.has_family("lm_tiny_efla"));
    assert!(backend.has_family("clf_deltanet"));
    assert!(!backend.has_family("lm_tiny_transformer"));
    assert!(!backend.describe().is_empty());
}

#[test]
fn server_decodes_greedily_on_cpu() {
    let backend = CpuBackend::new();
    let session = Session::init(&backend, "lm_tiny_efla", 11).unwrap();
    let mut server = Server::new(&session, 3).unwrap();
    for id in 0..(server.batch_size() as u64 + 1) {
        server
            .submit(GenRequest {
                id,
                prompt: vec![10, 20, 30],
                max_new: 4,
                temperature: 0.0,
                deadline: None,
                session_id: None,
            })
            .unwrap();
    }
    let results = server.run_to_completion().unwrap();
    assert_eq!(results.len(), server.batch_size() + 1);
    for r in &results {
        assert_eq!(r.tokens.len(), 4);
        assert!(r.tokens.iter().all(|&t| (0..256).contains(&t)));
    }
    // identical prompts + greedy sampling + independent slot states
    // => identical generations across slots
    let reference = &results[0].tokens;
    for r in &results[1..] {
        assert_eq!(&r.tokens, reference, "slot states must be independent");
    }
}

#[test]
fn other_mixer_variants_take_a_step() {
    let backend = CpuBackend::new();
    for family in ["lm_tiny_deltanet", "lm_tiny_efla_adaptive", "lm_tiny_efla_loose"] {
        let mut session = Session::init(&backend, family, 2).unwrap();
        let (t, y) = fixed_lm_batch(&session, 9);
        let m = session.step([t, y], 1e-3).unwrap();
        assert!(m.loss.is_finite(), "{family}: loss finite");
        assert!(m.grad_norm > 0.0, "{family}: gradient flows");
    }
}

#[test]
fn mad_family_builds_and_decodes() {
    // The MAD batch (16 x 128, d=128) is too heavy to train inside a
    // debug-mode unit test; init + the O(1)-state decode path cover the
    // family wiring (training is exercised by benches/table2_mad.rs).
    let backend = CpuBackend::new();
    let session = Session::init(&backend, "lm_mad_efla", 2).unwrap();
    assert_eq!(session.batch, 16);
    assert_eq!(session.seq, 128);
    assert_eq!(session.vocab().unwrap(), 64);
    let mut state = session.decode_state().unwrap();
    let tokens = vec![1i32; session.decode_batch().unwrap()];
    let logits = session.decode(&mut state, &tokens).unwrap();
    assert_eq!(logits.shape()[1], 64);
    assert!(logits.data().iter().all(|x| x.is_finite()));
}
