//! Property tests over the pure-Rust attention substrate.
//!
//! No proptest crate in the vendor set, so properties are swept over many
//! seeded random cases (shapes, chunk sizes, gate kinds) with shrinking
//! replaced by printing the failing case parameters.

#![forbid(unsafe_code)]

use efla::attention::{
    alpha_efla, alpha_rk, chunkwise_delta, gates, sequential_delta, Gate,
};
use efla::tensor::Tensor;
use efla::util::rng::Rng;

fn rand_t(rng: &mut Rng, shape: &[usize], sigma: f32) -> Tensor {
    Tensor::from_vec(shape, rng.normal_vec(shape.iter().product(), 0.0, sigma))
}

const SEED_BASE: u64 = 0x5EED_BA5E;

#[test]
fn prop_chunkwise_equals_sequential_any_shape() {
    for case in 0..40u64 {
        let mut rng = Rng::new(SEED_BASE + case);
        let l = rng.range(1, 80);
        let dk = [2, 3, 4, 8, 16][rng.range(0, 5)];
        let dv = [2, 3, 4, 8, 16][rng.range(0, 5)];
        let chunk = [1, 2, 3, 7, 16, 64][rng.range(0, 6)];
        let q = rand_t(&mut rng, &[l, dk], 1.0);
        let k = rand_t(&mut rng, &[l, dk], 0.6);
        let v = rand_t(&mut rng, &[l, dv], 1.0);
        let beta: Vec<f32> = (0..l).map(|_| rng.f32()).collect();
        let (o1, s1) = sequential_delta(Gate::Efla, &q, &k, &v, &beta);
        let (o2, s2) = chunkwise_delta(Gate::Efla, &q, &k, &v, &beta, chunk);
        let (od, sd) = (o1.max_abs_diff(&o2), s1.max_abs_diff(&s2));
        assert!(
            od < 5e-4 && sd < 5e-4,
            "case {case}: l={l} dk={dk} dv={dv} chunk={chunk} od={od} sd={sd}"
        );
    }
}

#[test]
fn prop_efla_state_norm_bounded_by_value_energy() {
    // EFLA's transition is a contraction along k: ||S|| stays O(sum ||v||).
    for case in 0..25u64 {
        let mut rng = Rng::new(SEED_BASE + 100 + case);
        let l = rng.range(8, 96);
        let d = [4, 8, 16][rng.range(0, 3)];
        let scale = 0.2 + 6.0 * rng.f32(); // include very stiff regimes
        let q = rand_t(&mut rng, &[l, d], 1.0);
        let k = rand_t(&mut rng, &[l, d], scale);
        let v = rand_t(&mut rng, &[l, d], 1.0);
        let beta: Vec<f32> = (0..l).map(|_| rng.f32()).collect();
        let (_, s) = sequential_delta(Gate::Efla, &q, &k, &v, &beta);
        let v_energy: f32 = (0..l).map(|t| {
            v.row(t).iter().map(|x| x * x).sum::<f32>().sqrt()
        }).sum();
        assert!(
            s.norm().is_finite() && s.norm() <= v_energy + 1.0,
            "case {case}: scale={scale} ||S||={} v_energy={v_energy}",
            s.norm()
        );
    }
}

#[test]
fn prop_transition_eigenvalue_contracts_for_efla_only() {
    for case in 0..200u64 {
        let mut rng = Rng::new(SEED_BASE + 200 + case);
        let beta = 4.0 * rng.f32();
        let lam = (10f32).powf(-4.0 + 8.0 * rng.f32());
        let ev = gates::transition_eigenvalue(Gate::Efla, beta, lam);
        assert!(
            (0.0..=1.0 + 1e-5).contains(&ev),
            "case {case}: beta={beta} lam={lam} ev={ev}"
        );
        // Euler escapes (-1,1) whenever beta*lambda > 2:
        if beta * lam > 2.0 {
            let ev_euler = gates::transition_eigenvalue(Gate::Euler, beta, lam);
            assert!(ev_euler < -1.0, "case {case}: euler ev {ev_euler}");
        }
    }
}

#[test]
fn prop_alpha_orders_sandwich_exact() {
    // For 0 < x < 1 the truncations alternate around the exact gate:
    // alpha_1 >= alpha_3 >= ... >= alpha_inf >= ... >= alpha_4 >= alpha_2.
    for case in 0..200u64 {
        let mut rng = Rng::new(SEED_BASE + 300 + case);
        let beta = 0.05 + 0.9 * rng.f32();
        let lam = 0.05 + 0.9 * rng.f32() / beta; // keep x = beta*lam in (0,1)
        let exact = alpha_efla(beta, lam);
        let a1 = alpha_rk(beta, lam, 1);
        let a2 = alpha_rk(beta, lam, 2);
        let a3 = alpha_rk(beta, lam, 3);
        let a4 = alpha_rk(beta, lam, 4);
        let eps = 1e-5;
        assert!(a1 >= exact - eps, "case {case}");
        assert!(a3 >= exact - eps, "case {case}");
        assert!(a2 <= exact + eps, "case {case}");
        assert!(a4 <= exact + eps, "case {case}");
        assert!(a1 >= a3 - eps && a2 <= a4 + eps, "case {case}");
    }
}

#[test]
fn prop_permuting_heads_is_permuting_outputs() {
    // Heads are independent: running two heads separately == concatenated.
    for case in 0..10u64 {
        let mut rng = Rng::new(SEED_BASE + 400 + case);
        let (l, d) = (rng.range(4, 40), 8);
        let mk = |rng: &mut Rng| rand_t(rng, &[l, d], 0.8);
        let (qa, ka, va) = (mk(&mut rng), mk(&mut rng), mk(&mut rng));
        let (qb, kb, vb) = (mk(&mut rng), mk(&mut rng), mk(&mut rng));
        let beta: Vec<f32> = (0..l).map(|_| rng.f32()).collect();
        let (oa, _) = chunkwise_delta(Gate::Efla, &qa, &ka, &va, &beta, 16);
        let (ob, _) = chunkwise_delta(Gate::Efla, &qb, &kb, &vb, &beta, 16);
        // re-run in the other order; results must be identical (no hidden state)
        let (ob2, _) = chunkwise_delta(Gate::Efla, &qb, &kb, &vb, &beta, 16);
        let (oa2, _) = chunkwise_delta(Gate::Efla, &qa, &ka, &va, &beta, 16);
        assert_eq!(oa, oa2, "case {case}: not deterministic");
        assert_eq!(ob, ob2, "case {case}");
    }
}

#[test]
fn prop_masked_no_op_tokens() {
    // beta = 0 tokens must not change the state or contribute output.
    for case in 0..20u64 {
        let mut rng = Rng::new(SEED_BASE + 500 + case);
        let (l, d) = (rng.range(6, 50), 8);
        let q = rand_t(&mut rng, &[l, d], 1.0);
        let k = rand_t(&mut rng, &[l, d], 0.7);
        let v = rand_t(&mut rng, &[l, d], 1.0);
        let mut beta: Vec<f32> = (0..l).map(|_| rng.f32()).collect();
        // zero out a random suffix
        let cut = rng.range(1, l + 1);
        for b in beta[..].iter_mut().skip(cut) {
            *b = 0.0;
        }
        let (_, s_full) = sequential_delta(Gate::Efla, &q, &k, &v, &beta);
        let (_, s_cut) = sequential_delta(
            Gate::Efla,
            &rand_slice(&q, cut),
            &rand_slice(&k, cut),
            &rand_slice(&v, cut),
            &beta[..cut],
        );
        assert!(
            s_full.max_abs_diff(&s_cut) < 1e-6,
            "case {case}: zero-beta suffix changed the state"
        );
    }
}

fn rand_slice(t: &Tensor, n: usize) -> Tensor {
    let cols = t.shape()[1];
    Tensor::from_vec(&[n, cols], t.data()[..n * cols].to_vec())
}

#[test]
fn prop_json_roundtrip_random_values() {
    use efla::util::json::{parse, Json};
    for case in 0..50u64 {
        let mut rng = Rng::new(SEED_BASE + 600 + case);
        let v = random_json(&mut rng, 3);
        let text = v.to_string();
        let back = parse(&text).unwrap_or_else(|e| panic!("case {case}: {e}\n{text}"));
        assert_eq!(v, back, "case {case}");
        let pretty = v.to_string_pretty();
        assert_eq!(parse(&pretty).unwrap(), v, "case {case} pretty");
    }

    fn random_json(rng: &mut Rng, depth: usize) -> Json {
        match if depth == 0 { rng.range(0, 4) } else { rng.range(0, 6) } {
            0 => Json::Null,
            1 => Json::Bool(rng.bernoulli(0.5)),
            2 => Json::Num((rng.normal() * 1e3).round() / 16.0),
            3 => {
                let n = rng.range(0, 8);
                let chars = ['a', '"', '\\', 'é', '\n', 'z'];
                Json::Str((0..n).map(|_| chars[rng.range(0, 6)]).collect())
            }
            4 => Json::Arr((0..rng.range(0, 4)).map(|_| random_json(rng, depth - 1)).collect()),
            _ => Json::Obj(
                (0..rng.range(0, 4))
                    .map(|i| (format!("k{i}"), random_json(rng, depth - 1)))
                    .collect(),
            ),
        }
    }
}

#[test]
fn prop_tokenizer_roundtrips_arbitrary_bytes() {
    use efla::data::tokenizer::Bpe;
    let corpus = "the quick brown fox jumps over the lazy dog. the quick brown fox again.";
    let bpe = Bpe::train(corpus, 300);
    for case in 0..30u64 {
        let mut rng = Rng::new(SEED_BASE + 700 + case);
        let n = rng.range(0, 200);
        let text: String = (0..n)
            .map(|_| {
                let c = rng.range(32, 127) as u8 as char;
                c
            })
            .collect();
        assert_eq!(bpe.decode(&bpe.encode(&text)), text, "case {case}");
    }
}

#[test]
fn prop_checkpoint_roundtrip_random_tensors() {
    use efla::coordinator::checkpoint;
    let dir = std::env::temp_dir().join(format!("efla_prop_ckpt_{}", std::process::id()));
    for case in 0..10u64 {
        let mut rng = Rng::new(SEED_BASE + 800 + case);
        let n = rng.range(1, 6);
        let tensors: Vec<Tensor> = (0..n)
            .map(|_| {
                let dims = rng.range(0, 3);
                let shape: Vec<usize> = (0..dims).map(|_| rng.range(1, 8)).collect();
                rand_t(&mut rng, &shape, 10.0)
            })
            .collect();
        let path = dir.join(format!("c{case}.ckpt"));
        checkpoint::save(&path, case, &tensors).unwrap();
        let (step, back) = checkpoint::load(&path).unwrap();
        assert_eq!(step, case);
        assert_eq!(tensors, back, "case {case}");
    }
    std::fs::remove_dir_all(&dir).ok();
}
