//! Prefill/decode equivalence: chunked parallel prefill must be a pure
//! throughput optimization — for any prompt and any `prefill_chunk`, the
//! logits and the slot state it produces are **bit-identical** to feeding
//! the prompt one token at a time through the decode path.
//!
//! CI runs this suite under the default environment, `EFLA_NUM_THREADS=1`
//! and `EFLA_FORCE_SCALAR=1` (the existing matrix legs), so the
//! equivalence is pinned per kernel tier and per thread count; the
//! cross-thread-count invariance is additionally pinned in-process below.

#![forbid(unsafe_code)]

use efla::coordinator::server::{GenRequest, Server, ServerConfig};
use efla::coordinator::session::Session;
use efla::runtime::{CpuBackend, HostValue};
use efla::util::rng::Rng;

fn prompt(rng: &mut Rng, len: usize, vocab: usize) -> Vec<i32> {
    (0..len).map(|_| rng.below(vocab as u64) as i32).collect()
}

/// Token-at-a-time reference: feed the prompt through the batched decode
/// path at `slot` (token 0 in the other slots, exactly like the serving
/// loop); returns the final state and the last decode's logits row.
fn decode_reference(
    session: &Session,
    slot: usize,
    tokens: &[i32],
) -> (Vec<HostValue>, Vec<f32>) {
    let b = session.decode_batch().unwrap();
    let vocab = session.vocab().unwrap();
    let mut state = session.decode_state().unwrap();
    let mut last = Vec::new();
    for &t in tokens {
        let mut step = vec![0i32; b];
        step[slot] = t;
        let logits = session.decode(&mut state, &step).unwrap();
        last = logits.data()[slot * vocab..(slot + 1) * vocab].to_vec();
    }
    (state, last)
}

/// The `slot` rows of every state tensor, flattened for comparison.
fn slot_rows(state: &[HostValue], batch: usize, slot: usize) -> Vec<Vec<f32>> {
    state
        .iter()
        .map(|hv| {
            let t = hv.as_f32().unwrap();
            let row = t.len() / batch;
            t.data()[slot * row..(slot + 1) * row].to_vec()
        })
        .collect()
}

fn check_family_bitwise(family: &str) {
    let backend = CpuBackend::new();
    let session = Session::init(&backend, family, 7).unwrap();
    let b = session.decode_batch().unwrap();
    let vocab = session.vocab().unwrap();
    let slot = 1 % b;
    let mut rng = Rng::new(71);
    let toks = prompt(&mut rng, 50, vocab);
    let (st_ref, logits_ref) = decode_reference(&session, slot, &toks);
    let rows_ref = slot_rows(&st_ref, b, slot);

    for chunk in [1usize, 7, 16, 50, 64] {
        let mut state = session.decode_state().unwrap();
        let mut logits = Vec::new();
        let mut pos = 0;
        while pos < toks.len() {
            let end = (pos + chunk).min(toks.len());
            logits = session
                .prefill(&mut state, slot, &toks[pos..end])
                .unwrap()
                .data()
                .to_vec();
            pos = end;
        }
        assert_eq!(
            logits, logits_ref,
            "{family}: prefill_chunk={chunk} logits must match token-at-a-time bitwise"
        );
        assert_eq!(
            slot_rows(&state, b, slot),
            rows_ref,
            "{family}: prefill_chunk={chunk} slot state must match token-at-a-time bitwise"
        );
    }
}

#[test]
fn prefill_matches_token_at_a_time_bitwise_efla() {
    check_family_bitwise("lm_tiny_efla");
}

#[test]
fn prefill_matches_token_at_a_time_bitwise_deltanet() {
    // DeltaNet exercises the l2-normalized q/k path.
    check_family_bitwise("lm_tiny_deltanet");
}

#[test]
fn prefill_matches_token_at_a_time_bitwise_efla_adaptive() {
    // Adaptive decay exercises the per-head softplus gate composition.
    check_family_bitwise("lm_tiny_efla_adaptive");
}

#[test]
fn prefill_is_thread_count_invariant() {
    let s1 = Session::init(&CpuBackend::with_threads(1), "lm_tiny_efla", 9).unwrap();
    let s4 = Session::init(&CpuBackend::with_threads(4), "lm_tiny_efla", 9).unwrap();
    let vocab = s1.vocab().unwrap();
    let b = s1.decode_batch().unwrap();
    let mut rng = Rng::new(5);
    let toks = prompt(&mut rng, 40, vocab);
    let mut st1 = s1.decode_state().unwrap();
    let mut st4 = s4.decode_state().unwrap();
    let l1 = s1.prefill(&mut st1, 0, &toks).unwrap();
    let l4 = s4.prefill(&mut st4, 0, &toks).unwrap();
    assert_eq!(l1.data(), l4.data(), "prefill logits must be thread-count invariant");
    assert_eq!(slot_rows(&st1, b, 0), slot_rows(&st4, b, 0));
}

/// Greedy-serve a fixed request mix and return the generated tokens.
fn serve_greedy(session: &Session, cfg: ServerConfig) -> Vec<Vec<i32>> {
    let vocab = session.vocab().unwrap();
    let mut server = Server::with_config(session, 42, cfg).unwrap();
    let mut rng = Rng::new(33);
    let n_req = server.batch_size() as u64 + 3;
    for id in 0..n_req {
        let len = rng.range(3, 80);
        server
            .submit(GenRequest {
                id,
                prompt: prompt(&mut rng, len, vocab),
                max_new: 4,
                temperature: 0.0,
            })
            .unwrap();
    }
    let results = server.run_to_completion().unwrap();
    assert_eq!(results.len(), n_req as usize);
    // Token accounting invariant holds in every mode.
    assert_eq!(
        server.stats.prefill_tokens + server.stats.decode_tokens,
        server.stats.tokens_processed
    );
    results.into_iter().map(|r| r.tokens).collect()
}

#[test]
fn server_chunked_prefill_matches_token_at_a_time() {
    let backend = CpuBackend::new();
    let session = Session::init(&backend, "lm_tiny_efla", 11).unwrap();
    let legacy = serve_greedy(
        &session,
        ServerConfig { prefill_chunk: 0, prefill_token_budget: 0, ..ServerConfig::default() },
    );
    for chunk in [1usize, 5, 64] {
        for budget in [0usize, 32] {
            let chunked = serve_greedy(
                &session,
                ServerConfig {
                    prefill_chunk: chunk,
                    prefill_token_budget: budget,
                    ..ServerConfig::default()
                },
            );
            assert_eq!(
                chunked, legacy,
                "prefill_chunk={chunk} budget={budget} must generate identical tokens"
            );
        }
    }
}

#[test]
fn server_reports_prefill_decode_split_and_ttft() {
    let backend = CpuBackend::new();
    let session = Session::init(&backend, "lm_tiny_efla", 13).unwrap();
    let vocab = session.vocab().unwrap();
    let mut server = Server::new(&session, 1).unwrap();
    let mut rng = Rng::new(2);
    for id in 0..3u64 {
        server
            .submit(GenRequest {
                id,
                prompt: prompt(&mut rng, 30, vocab),
                max_new: 5,
                temperature: 0.0,
            })
            .unwrap();
    }
    let results = server.run_to_completion().unwrap();
    assert_eq!(results.len(), 3);
    // 3 prompts of 30 tokens through the prefill path, 4 decodes each
    // (the first generated token rides on the prompt's last logits).
    assert_eq!(server.stats.prefill_tokens, 90);
    assert_eq!(server.stats.decode_tokens, 12);
    assert_eq!(server.stats.tokens_processed, 102);
    assert_eq!(server.stats.ttft_count, 3);
    assert!(server.stats.mean_ttft_secs() > 0.0);
    for r in &results {
        assert_eq!(r.tokens.len(), 5);
        assert!(r.ttft_secs > 0.0);
    }
}
