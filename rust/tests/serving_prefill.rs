//! Prefill/decode equivalence: chunked parallel prefill must be a pure
//! throughput optimization — for any prompt and any `prefill_chunk`, the
//! logits and the slot state it produces are **bit-identical** to feeding
//! the prompt one token at a time through the decode path.
//!
//! The slot-batched decode entry (`decode_slots`) carries the same
//! contract along the occupancy axis: with every serving matmul keyed on
//! the slot capacity, a slot's logits and state rows must not depend on
//! which other slots decode alongside it. The occupancy matrix below pins
//! that bit-for-bit across sparse, partial, full, and churning patterns.
//!
//! CI runs this suite under the default environment, `EFLA_NUM_THREADS=1`
//! and `EFLA_FORCE_SCALAR=1` (the existing matrix legs), so the
//! equivalence is pinned per kernel tier and per thread count; the
//! cross-thread-count invariance is additionally pinned in-process below.

#![forbid(unsafe_code)]

use efla::coordinator::server::{GenRequest, Server, ServerConfig};
use efla::coordinator::session::Session;
use efla::runtime::{CpuBackend, HostValue};
use efla::util::rng::Rng;

fn prompt(rng: &mut Rng, len: usize, vocab: usize) -> Vec<i32> {
    (0..len).map(|_| rng.below(vocab as u64) as i32).collect()
}

/// Token-at-a-time reference: feed the prompt through the batched decode
/// path at `slot` (token 0 in the other slots, exactly like the serving
/// loop); returns the final state and the last decode's logits row.
fn decode_reference(
    session: &Session,
    slot: usize,
    tokens: &[i32],
) -> (Vec<HostValue>, Vec<f32>) {
    let b = session.decode_batch().unwrap();
    let vocab = session.vocab().unwrap();
    let mut state = session.decode_state().unwrap();
    let mut last = Vec::new();
    for &t in tokens {
        let mut step = vec![0i32; b];
        step[slot] = t;
        let logits = session.decode(&mut state, &step).unwrap();
        last = logits.data()[slot * vocab..(slot + 1) * vocab].to_vec();
    }
    (state, last)
}

/// The `slot` rows of every state tensor, flattened for comparison.
fn slot_rows(state: &[HostValue], batch: usize, slot: usize) -> Vec<Vec<f32>> {
    state
        .iter()
        .map(|hv| {
            let t = hv.as_f32().unwrap();
            let row = t.len() / batch;
            t.data()[slot * row..(slot + 1) * row].to_vec()
        })
        .collect()
}

fn check_family_bitwise(family: &str) {
    let backend = CpuBackend::new();
    let session = Session::init(&backend, family, 7).unwrap();
    let b = session.decode_batch().unwrap();
    let vocab = session.vocab().unwrap();
    let slot = 1 % b;
    let mut rng = Rng::new(71);
    let toks = prompt(&mut rng, 50, vocab);
    let (st_ref, logits_ref) = decode_reference(&session, slot, &toks);
    let rows_ref = slot_rows(&st_ref, b, slot);

    for chunk in [1usize, 7, 16, 50, 64] {
        let mut state = session.decode_state().unwrap();
        let mut logits = Vec::new();
        let mut pos = 0;
        while pos < toks.len() {
            let end = (pos + chunk).min(toks.len());
            logits = session
                .prefill(&mut state, slot, &toks[pos..end])
                .unwrap()
                .data()
                .to_vec();
            pos = end;
        }
        assert_eq!(
            logits, logits_ref,
            "{family}: prefill_chunk={chunk} logits must match token-at-a-time bitwise"
        );
        assert_eq!(
            slot_rows(&state, b, slot),
            rows_ref,
            "{family}: prefill_chunk={chunk} slot state must match token-at-a-time bitwise"
        );
    }
}

#[test]
fn prefill_matches_token_at_a_time_bitwise_efla() {
    check_family_bitwise("lm_tiny_efla");
}

#[test]
fn prefill_matches_token_at_a_time_bitwise_deltanet() {
    // DeltaNet exercises the l2-normalized q/k path.
    check_family_bitwise("lm_tiny_deltanet");
}

#[test]
fn prefill_matches_token_at_a_time_bitwise_efla_adaptive() {
    // Adaptive decay exercises the per-head softplus gate composition.
    check_family_bitwise("lm_tiny_efla_adaptive");
}

#[test]
fn prefill_is_thread_count_invariant() {
    let s1 = Session::init(&CpuBackend::with_threads(1), "lm_tiny_efla", 9).unwrap();
    let s4 = Session::init(&CpuBackend::with_threads(4), "lm_tiny_efla", 9).unwrap();
    let vocab = s1.vocab().unwrap();
    let b = s1.decode_batch().unwrap();
    let mut rng = Rng::new(5);
    let toks = prompt(&mut rng, 40, vocab);
    let mut st1 = s1.decode_state().unwrap();
    let mut st4 = s4.decode_state().unwrap();
    let l1 = s1.prefill(&mut st1, 0, &toks).unwrap();
    let l4 = s4.prefill(&mut st4, 0, &toks).unwrap();
    assert_eq!(l1.data(), l4.data(), "prefill logits must be thread-count invariant");
    assert_eq!(slot_rows(&st1, b, 0), slot_rows(&st4, b, 0));

    // Batched decode over the warmed slot is thread-count invariant too.
    let all: Vec<usize> = (0..b).collect();
    let next = vec![3i32; b];
    let d1 = s1.decode_slots(&mut st1, &all, &next).unwrap();
    let d4 = s4.decode_slots(&mut st4, &all, &next).unwrap();
    assert_eq!(d1.data(), d4.data(), "batched decode logits must be thread-count invariant");
    assert_eq!(st1, st4, "batched decode state must be thread-count invariant");
}

/// Warm every slot with a distinct prompt through the prefill path;
/// returns the warmed state and one greedy next token per slot.
fn warm_state(session: &Session, seed: u64) -> (Vec<HostValue>, Vec<i32>) {
    let b = session.decode_batch().unwrap();
    let vocab = session.vocab().unwrap();
    let mut rng = Rng::new(seed);
    let mut state = session.decode_state().unwrap();
    let mut next = vec![0i32; b];
    for s in 0..b {
        let toks = prompt(&mut rng, 8 + 3 * s, vocab);
        let logits = session.prefill(&mut state, s, &toks).unwrap();
        let row = logits.data();
        let mut best = 0usize;
        for j in 1..row.len() {
            if row[j] > row[best] {
                best = j;
            }
        }
        next[s] = best as i32;
    }
    (state, next)
}

/// Occupancy matrix: a slot's decode bits must not depend on which other
/// slots share the step. Every pattern is compared row-for-row against
/// the slot decoding alone from the same warmed state, and the state
/// rows of the idle slots must come through untouched.
fn check_occupancy_matrix(family: &str) {
    let backend = CpuBackend::new();
    let session = Session::init(&backend, family, 7).unwrap();
    assert!(session.supports_batched_decode(), "{family}: LM backends expose batched decode");
    let b = session.decode_batch().unwrap();
    let vocab = session.vocab().unwrap();
    assert!(b >= 4, "{family}: occupancy patterns assume at least 4 slots");
    let (base, next) = warm_state(&session, 71);

    // Solo references: each slot decoded alone from the warmed state.
    let mut solo_logits = Vec::new();
    let mut solo_rows = Vec::new();
    for s in 0..b {
        let mut st = base.clone();
        let l = session.decode_slots(&mut st, &[s], &[next[s]]).unwrap();
        solo_logits.push(l.data().to_vec());
        solo_rows.push(slot_rows(&st, b, s));
    }

    let patterns: &[&[usize]] = &[&[0], &[2], &[0, 3], &[1, 2, 3], &[0, 1, 2, 3]];
    for pat in patterns {
        let mut st = base.clone();
        let toks: Vec<i32> = pat.iter().map(|&s| next[s]).collect();
        let logits = session.decode_slots(&mut st, pat, &toks).unwrap();
        for (i, &s) in pat.iter().enumerate() {
            assert_eq!(
                &logits.data()[i * vocab..(i + 1) * vocab],
                &solo_logits[s][..],
                "{family}: pattern {pat:?} slot {s} logits must match solo decode bitwise"
            );
            assert_eq!(
                slot_rows(&st, b, s),
                solo_rows[s],
                "{family}: pattern {pat:?} slot {s} state must match solo decode bitwise"
            );
        }
        for s in (0..b).filter(|s| !pat.contains(s)) {
            assert_eq!(
                slot_rows(&st, b, s),
                slot_rows(&base, b, s),
                "{family}: pattern {pat:?} idle slot {s} state must be untouched"
            );
        }
    }

    // Full occupancy must also be bit-identical to the legacy dense-batch
    // decode entry — the batched path is a re-plumbing, not a re-derivation.
    let all: Vec<usize> = (0..b).collect();
    let mut st_batched = base.clone();
    let lb = session.decode_slots(&mut st_batched, &all, &next).unwrap();
    let mut st_legacy = base.clone();
    let ll = session.decode(&mut st_legacy, &next).unwrap();
    assert_eq!(lb.data(), ll.data(), "{family}: full-occupancy logits vs legacy decode");
    assert_eq!(st_batched, st_legacy, "{family}: full-occupancy state vs legacy decode");
}

#[test]
fn batched_decode_is_occupancy_invariant_efla() {
    check_occupancy_matrix("lm_tiny_efla");
}

#[test]
fn batched_decode_is_occupancy_invariant_deltanet() {
    check_occupancy_matrix("lm_tiny_deltanet");
}

#[test]
fn batched_decode_churn_matches_solo_trajectories() {
    // Slots join and leave mid-stream — the arrival/departure order seen
    // by a continuous-batching server. Every step a slot participates in
    // must reproduce its solo trajectory bit-for-bit.
    let backend = CpuBackend::new();
    let session = Session::init(&backend, "lm_tiny_efla", 7).unwrap();
    let b = session.decode_batch().unwrap();
    let vocab = session.vocab().unwrap();
    assert!(b >= 4, "churn schedule assumes at least 4 slots");
    let (base, _) = warm_state(&session, 73);
    let schedule: &[&[usize]] = &[&[0, 1], &[0, 1, 2], &[1, 2], &[1, 2, 3], &[3], &[0, 3]];

    // Per-slot token sequences, one token per step the slot is active.
    let mut rng = Rng::new(19);
    let seq: Vec<Vec<i32>> = (0..b)
        .map(|s| {
            let n = schedule.iter().filter(|a| a.contains(&s)).count();
            prompt(&mut rng, n, vocab)
        })
        .collect();

    // Solo trajectories: each slot decoded alone, step by step.
    let mut solo: Vec<Vec<Vec<f32>>> = Vec::new();
    let mut solo_state: Vec<Vec<Vec<f32>>> = Vec::new();
    for s in 0..b {
        let mut st = base.clone();
        let mut steps = Vec::new();
        for &t in &seq[s] {
            let l = session.decode_slots(&mut st, &[s], &[t]).unwrap();
            steps.push(l.data().to_vec());
        }
        solo.push(steps);
        solo_state.push(slot_rows(&st, b, s));
    }

    // The same trajectories interleaved through one shared slot block.
    let mut st = base.clone();
    let mut used = vec![0usize; b];
    for active in schedule {
        let toks: Vec<i32> = active.iter().map(|&s| seq[s][used[s]]).collect();
        let logits = session.decode_slots(&mut st, active, &toks).unwrap();
        for (i, &s) in active.iter().enumerate() {
            assert_eq!(
                &logits.data()[i * vocab..(i + 1) * vocab],
                &solo[s][used[s]][..],
                "slot {s} step {} must match its solo trajectory bitwise",
                used[s]
            );
            used[s] += 1;
        }
    }
    for s in 0..b {
        assert_eq!(slot_rows(&st, b, s), solo_state[s], "slot {s} final state after churn");
    }
}

/// Greedy-serve a fixed request mix and return the generated tokens.
fn serve_greedy(session: &Session, cfg: ServerConfig) -> Vec<Vec<i32>> {
    let vocab = session.vocab().unwrap();
    let mut server = Server::with_config(session, 42, cfg).unwrap();
    let mut rng = Rng::new(33);
    let n_req = server.batch_size() as u64 + 3;
    for id in 0..n_req {
        let len = rng.range(3, 80);
        server
            .submit(GenRequest {
                id,
                prompt: prompt(&mut rng, len, vocab),
                max_new: 4,
                temperature: 0.0,
                deadline: None,
                session_id: None,
            })
            .unwrap();
    }
    let results = server.run_to_completion().unwrap();
    assert_eq!(results.len(), n_req as usize);
    // Token accounting invariant holds in every mode.
    assert_eq!(
        server.stats.prefill_tokens + server.stats.decode_tokens,
        server.stats.tokens_processed
    );
    results.into_iter().map(|r| r.tokens).collect()
}

#[test]
fn server_chunked_prefill_matches_token_at_a_time() {
    let backend = CpuBackend::new();
    let session = Session::init(&backend, "lm_tiny_efla", 11).unwrap();
    let legacy = serve_greedy(
        &session,
        ServerConfig { prefill_chunk: 0, prefill_token_budget: 0, ..ServerConfig::default() },
    );
    for chunk in [1usize, 5, 64] {
        for budget in [0usize, 32] {
            let chunked = serve_greedy(
                &session,
                ServerConfig {
                    prefill_chunk: chunk,
                    prefill_token_budget: budget,
                    ..ServerConfig::default()
                },
            );
            assert_eq!(
                chunked, legacy,
                "prefill_chunk={chunk} budget={budget} must generate identical tokens"
            );
        }
    }
}

#[test]
fn server_reports_prefill_decode_split_and_ttft() {
    let backend = CpuBackend::new();
    let session = Session::init(&backend, "lm_tiny_efla", 13).unwrap();
    let vocab = session.vocab().unwrap();
    let mut server = Server::new(&session, 1).unwrap();
    let mut rng = Rng::new(2);
    for id in 0..3u64 {
        server
            .submit(GenRequest {
                id,
                prompt: prompt(&mut rng, 30, vocab),
                max_new: 5,
                temperature: 0.0,
                deadline: None,
                session_id: None,
            })
            .unwrap();
    }
    let results = server.run_to_completion().unwrap();
    assert_eq!(results.len(), 3);
    // 3 prompts of 30 tokens through the prefill path, 4 decodes each
    // (the first generated token rides on the prompt's last logits).
    assert_eq!(server.stats.prefill_tokens, 90);
    assert_eq!(server.stats.decode_tokens, 12);
    assert_eq!(server.stats.tokens_processed, 102);
    assert_eq!(server.stats.ttft_count, 3);
    assert!(server.stats.mean_ttft_secs() > 0.0);
    for r in &results {
        assert_eq!(r.tokens.len(), 5);
        assert!(r.ttft_secs > 0.0);
    }
}
