//! Perf probes (run manually: `cargo test --release --test perf_probe -- --ignored --nocapture`).
//!
//! Produces the §Perf before/after numbers in EXPERIMENTS.md:
//!   * tokenizer: naive stream encode vs word-cached encode;
//!   * BPE training throughput (word-histogram algorithm);
//!   * data pipeline: inline batch generation vs prefetched;
//!   * backend step breakdown: data vs step (fwd+bwd+AdamW).

#![forbid(unsafe_code)]

use std::time::Instant;

use efla::coordinator::config::RunConfig;
use efla::coordinator::session::Session;
use efla::data::corpus::{Corpus, CorpusConfig};
use efla::data::loader::{Prefetcher, TokenStream};
use efla::data::tokenizer::Bpe;
use efla::runtime::open_backend;

fn secs<F: FnMut()>(mut f: F) -> f64 {
    let t0 = Instant::now();
    f();
    t0.elapsed().as_secs_f64()
}

#[test]
#[ignore]
fn perf_tokenizer_encode_paths() {
    let mut corpus = Corpus::new(1, CorpusConfig::default());
    let text = corpus.text(1_000_000);
    let t_train = secs(|| {
        std::hint::black_box(Bpe::train(&text[..300_000], 1024));
    });
    let bpe = Bpe::train(&text[..300_000], 1024);
    let mut n1 = 0;
    let t_naive = secs(|| {
        n1 = bpe.encode(&text[..100_000]).len();
    });
    let mut n2 = 0;
    let t_cached = secs(|| {
        n2 = bpe.encode_cached(&text).len();
    });
    println!("BPE train(300KB -> 1024 vocab): {t_train:.2}s");
    println!("encode naive     (100KB): {t_naive:.3}s  ({:.0} KB/s)", 100.0 / t_naive);
    println!("encode cached    (1MB):   {t_cached:.3}s ({:.0} KB/s)", 1000.0 / t_cached);
    println!("tokens: naive/100KB={n1} cached/1MB={n2}");
}

#[test]
#[ignore]
fn perf_prefetch_overlap() {
    let mut corpus = Corpus::new(2, CorpusConfig::default());
    let text = corpus.text(2_000_000);
    let ids: Vec<i32> = text.bytes().map(|b| b as i32).collect();
    let mut stream = TokenStream::new(ids.clone());
    let t_inline = secs(|| {
        for _ in 0..50 {
            std::hint::black_box(stream.lm_batch(8, 256));
        }
    });
    let mut stream2 = TokenStream::new(ids);
    let pf = Prefetcher::spawn(4, move || stream2.lm_batch(8, 256));
    let _ = pf.next(); // warm
    let t_pf = secs(|| {
        for _ in 0..50 {
            std::hint::black_box(pf.next());
        }
    });
    println!("batch gen inline: {:.3}ms/batch", t_inline * 20.0);
    println!("batch via prefetcher (consumer view): {:.3}ms/batch", t_pf * 20.0);
}

#[test]
#[ignore]
fn perf_step_breakdown() {
    let backend = open_backend(std::path::Path::new("artifacts")).unwrap();
    let mut session = Session::init(backend.as_ref(), "lm_tiny_efla", 42).unwrap();
    let cfg = RunConfig { corpus_bytes: 200_000, ..Default::default() };
    let (pf, _) = efla::coordinator::trainer::lm_data(&cfg, session.batch, session.seq).unwrap();

    // warm the step path (PJRT: compiles the executable; CPU: page-in)
    let (t, y) = pf.next();
    session.step([t, y], 1e-3).unwrap();

    let iters = 20;
    let mut t_data = 0.0;
    let mut t_exec = 0.0;
    for _ in 0..iters {
        let t0 = Instant::now();
        let (t, y) = pf.next();
        t_data += t0.elapsed().as_secs_f64();
        let t2 = Instant::now();
        session.step([t, y], 1e-3).unwrap();
        t_exec += t2.elapsed().as_secs_f64();
    }
    let n = iters as f64;
    println!(
        "tiny step breakdown ({} backend): data {:.2}ms | step(fwd+bwd+adamw) {:.2}ms",
        backend.name(),
        t_data / n * 1e3,
        t_exec / n * 1e3
    );
    let p = session.param_elems();
    println!(
        "state traffic per step: 3 x {:.2}MB params x 2 directions inside step()",
        p as f64 * 4.0 / 1e6
    );
}
