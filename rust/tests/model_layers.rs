//! Layered-model integration tests: orchestrator behavior (ported from the
//! old monolithic `model.rs` unit tests) plus the executor determinism
//! contract — the multi-threaded (batch × head) executor must produce
//! bit-identical losses, gradients and decode trajectories to `threads=1`.

#![forbid(unsafe_code)]

use efla::runtime::cpu::config::family_config;
use efla::runtime::cpu::exec::Executor;
use efla::runtime::cpu::model::{clf_loss, lm_loss};
use efla::runtime::cpu::params::ParamSet;
use efla::runtime::{Backend, CpuBackend, ModelSession as _};
use efla::util::rng::Rng;

fn lm_batch(vocab: usize, rows: usize, seed: u64) -> (Vec<i32>, Vec<i32>) {
    let mut rng = Rng::new(seed);
    let toks: Vec<i32> = (0..rows).map(|_| rng.below(vocab as u64) as i32).collect();
    let tgts: Vec<i32> = (0..rows).map(|_| rng.below(vocab as u64) as i32).collect();
    (toks, tgts)
}

#[test]
fn multithreaded_lm_grads_bit_identical_to_serial() {
    let families =
        ["lm_tiny_efla", "lm_tiny_deltanet", "lm_tiny_efla_adaptive", "lm_tiny_efla_loose"];
    for family in families {
        let cfg = family_config(family).unwrap();
        let params = ParamSet::init(&cfg, 7);
        let (b, l) = (2usize, 16usize);
        let (toks, tgts) = lm_batch(cfg.vocab, b * l, 2);

        let e1 = Executor::serial();
        let mut g1 = params.zeros_like();
        let s1 = lm_loss(&cfg, &params, &e1, &toks, &tgts, b, l, Some(&mut g1)).unwrap();

        for threads in [2usize, 4] {
            let en = Executor::new(threads);
            let mut gn = params.zeros_like();
            let sn = lm_loss(&cfg, &params, &en, &toks, &tgts, b, l, Some(&mut gn)).unwrap();
            assert_eq!(
                s1.loss_mean.to_bits(),
                sn.loss_mean.to_bits(),
                "{family}: loss differs at {threads} threads"
            );
            for (i, (a, c)) in g1.iter().zip(gn.iter()).enumerate() {
                assert_eq!(
                    a.data(),
                    c.data(),
                    "{family}: grad tensor {} ({}) differs at {threads} threads",
                    i,
                    params.names()[i]
                );
            }
        }
    }
}

#[test]
fn multithreaded_clf_grads_bit_identical_to_serial() {
    let cfg = family_config("clf_efla").unwrap();
    let params = ParamSet::init(&cfg, 11);
    let b = 2usize;
    let mut rng = Rng::new(5);
    let pixels: Vec<f32> = (0..b * cfg.seq).map(|_| rng.f32()).collect();
    let labels = vec![3i32, 8];

    let e1 = Executor::serial();
    let mut g1 = params.zeros_like();
    let s1 = clf_loss(&cfg, &params, &e1, &pixels, &labels, b, Some(&mut g1)).unwrap();

    let e4 = Executor::new(4);
    let mut g4 = params.zeros_like();
    let s4 = clf_loss(&cfg, &params, &e4, &pixels, &labels, b, Some(&mut g4)).unwrap();

    assert_eq!(s1.loss_mean.to_bits(), s4.loss_mean.to_bits());
    for (a, c) in g1.iter().zip(g4.iter()) {
        assert_eq!(a.data(), c.data());
    }
}

#[test]
fn multithreaded_decode_bit_identical_to_serial() {
    let b1 = CpuBackend::with_threads(1);
    let b4 = CpuBackend::with_threads(4);
    let s1 = b1.open_session("lm_tiny_efla", 9).unwrap();
    let s4 = b4.open_session("lm_tiny_efla", 9).unwrap();
    assert_eq!(s1.threads(), 1);
    assert_eq!(s4.threads(), 4);

    let mut st1 = s1.decode_state().unwrap();
    let mut st4 = s4.decode_state().unwrap();
    let batch = s1.decode_batch().unwrap();
    for step in 0..4 {
        let tokens = vec![(40 + step) as i32; batch];
        let l1 = s1.decode(&mut st1, &tokens).unwrap();
        let l4 = s4.decode(&mut st4, &tokens).unwrap();
        assert_eq!(l1.data(), l4.data(), "decode logits differ at step {step}");
        for (a, c) in st1.iter().zip(st4.iter()) {
            assert_eq!(
                a.as_f32().unwrap().data(),
                c.as_f32().unwrap().data(),
                "decode state differs at step {step}"
            );
        }
    }
}

/// Model-level finite-difference gradient check under whatever matmul
/// dispatch tier is active. CI runs the suite both with default dispatch
/// and with EFLA_FORCE_SCALAR=1, so this check covers the SIMD and scalar
/// paths (see tests/grad_check_paths.rs for the in-process two-tier run).
#[test]
fn lm_gradients_match_finite_differences() {
    let cfg = family_config("lm_tiny_efla").unwrap();
    let mut params = ParamSet::init(&cfg, 3);
    let exec = Executor::serial();
    let (b, l) = (1usize, 5usize);
    let (toks, tgts) = lm_batch(cfg.vocab, b * l, 9);

    let mut grads = params.zeros_like();
    lm_loss(&cfg, &params, &exec, &toks, &tgts, b, l, Some(&mut grads)).unwrap();

    let h = 2e-2f32;
    let pi = params.idx("embed");
    let n_elems = params.tensor(pi).len();
    for idx in (0..n_elems).step_by((n_elems / 9).max(1)) {
        let orig = params.tensor(pi).data()[idx];
        params.tensor_mut(pi).data_mut()[idx] = orig + h;
        let lp = lm_loss(&cfg, &params, &exec, &toks, &tgts, b, l, None).unwrap().loss_mean;
        params.tensor_mut(pi).data_mut()[idx] = orig - h;
        let lm = lm_loss(&cfg, &params, &exec, &toks, &tgts, b, l, None).unwrap().loss_mean;
        params.tensor_mut(pi).data_mut()[idx] = orig;
        let fd = (lp as f64 - lm as f64) / (2.0 * h as f64);
        let analytic = grads[pi].data()[idx] as f64;
        assert!(
            (analytic - fd).abs() < 2e-2 * (1.0 + fd.abs()),
            "embed[{idx}]: analytic {analytic} vs fd {fd}"
        );
    }
}

#[test]
fn lm_forward_loss_near_uniform_at_init() {
    let cfg = family_config("lm_tiny_efla").unwrap();
    let params = ParamSet::init(&cfg, 42);
    let exec = Executor::new(0);
    let (toks, tgts) = lm_batch(cfg.vocab, cfg.batch * cfg.seq, 1);
    let stats =
        lm_loss(&cfg, &params, &exec, &toks, &tgts, cfg.batch, cfg.seq, None).unwrap();
    assert!(stats.loss_mean.is_finite());
    // Untrained model on uniform random targets: mean CE near ln(vocab).
    let expect = (cfg.vocab as f32).ln();
    assert!(
        (stats.loss_mean - expect).abs() < 1.5,
        "loss {} vs ln(V) {expect}",
        stats.loss_mean
    );
    assert_eq!(stats.count as usize, cfg.batch * cfg.seq);
}

#[test]
fn lm_gradients_are_finite_and_nonzero() {
    let families =
        ["lm_tiny_efla", "lm_tiny_deltanet", "lm_tiny_efla_adaptive", "lm_tiny_efla_loose"];
    for family in families {
        let cfg = family_config(family).unwrap();
        let params = ParamSet::init(&cfg, 7);
        let exec = Executor::new(0);
        let (b, l) = (2usize, 24usize);
        let (toks, tgts) = lm_batch(cfg.vocab, b * l, 2);
        let mut grads = params.zeros_like();
        lm_loss(&cfg, &params, &exec, &toks, &tgts, b, l, Some(&mut grads)).unwrap();
        let mut total = 0f64;
        for (g, name) in grads.iter().zip(params.names()) {
            for &x in g.data() {
                assert!(x.is_finite(), "{family}: non-finite grad in {name}");
            }
            total += g.data().iter().map(|&x| (x as f64).abs()).sum::<f64>();
        }
        assert!(total > 0.0, "{family}: all-zero gradients");
        // embedding (tied head) must receive gradient
        let ge = &grads[params.idx("embed")];
        assert!(ge.norm() > 0.0, "{family}: embed grad zero");
    }
}

#[test]
fn masked_targets_are_ignored() {
    let cfg = family_config("lm_tiny_efla").unwrap();
    let params = ParamSet::init(&cfg, 42);
    let exec = Executor::new(0);
    let (b, l) = (1usize, 8usize);
    let (toks, mut tgts) = lm_batch(cfg.vocab, b * l, 3);
    for t in tgts.iter_mut().skip(1) {
        *t = -1;
    }
    let stats = lm_loss(&cfg, &params, &exec, &toks, &tgts, b, l, None).unwrap();
    assert_eq!(stats.count as usize, 1);
    assert!(stats.loss_sum.is_finite());
}

#[test]
fn out_of_range_tokens_rejected() {
    let cfg = family_config("lm_tiny_efla").unwrap();
    let params = ParamSet::init(&cfg, 42);
    let exec = Executor::new(0);
    let (b, l) = (1usize, 4usize);
    let (mut toks, tgts) = lm_batch(cfg.vocab, b * l, 4);
    toks[0] = cfg.vocab as i32;
    assert!(lm_loss(&cfg, &params, &exec, &toks, &tgts, b, l, None).is_err());
}
