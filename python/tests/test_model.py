"""L2 model graphs: shapes, variants, training dynamics, serving parity."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from compile import classifier as clf
from compile import model as mdl
from compile import train as trn

CFG = mdl.preset_with_mixer("tiny", "efla")


def params_for(cfg, seed=0):
    return mdl.init_params(jax.random.PRNGKey(seed), cfg)


def tokens_for(cfg, b=2, l=32, seed=0):
    key = jax.random.PRNGKey(seed)
    toks = jax.random.randint(key, (b, l), 0, cfg.vocab)
    tgts = jnp.concatenate([toks[:, 1:], -jnp.ones((b, 1), jnp.int32)], axis=1)
    return toks, tgts


class TestForward:
    @pytest.mark.parametrize("mixer", ["efla", "deltanet", "efla_adaptive", "efla_loose"])
    def test_variants_forward_shapes(self, mixer):
        cfg = mdl.preset_with_mixer("tiny", mixer)
        params = params_for(cfg)
        toks, _ = tokens_for(cfg)
        logits = mdl.forward(cfg, params, toks)
        assert logits.shape == (2, 32, cfg.vocab)
        assert bool(jnp.all(jnp.isfinite(logits)))

    def test_variants_differ_numerically(self):
        outs = {}
        for mixer in ["efla", "deltanet", "efla_loose"]:
            cfg = mdl.preset_with_mixer("tiny", mixer)
            params = params_for(cfg, seed=0)
            toks, _ = tokens_for(cfg)
            outs[mixer] = mdl.forward(cfg, params, toks)
        assert float(jnp.abs(outs["efla"] - outs["deltanet"]).max()) > 1e-3
        assert float(jnp.abs(outs["efla"] - outs["efla_loose"]).max()) > 1e-3

    def test_causality(self):
        # changing a future token must not change past logits
        params = params_for(CFG)
        toks, _ = tokens_for(CFG)
        logits1 = mdl.forward(CFG, params, toks)
        toks2 = toks.at[:, 20].set((toks[:, 20] + 1) % CFG.vocab)
        logits2 = mdl.forward(CFG, params, toks2)
        np.testing.assert_allclose(logits1[:, :20], logits2[:, :20], atol=1e-5)
        assert float(jnp.abs(logits1[:, 20:] - logits2[:, 20:]).max()) > 1e-4

    def test_param_count_matches_spec(self):
        # tiny: embed 256*64 + per-layer + final norm; just pin the number so
        # architecture drift is caught.
        assert CFG.param_count() == 149_636

    def test_100m_preset_is_about_100m(self):
        n = mdl.PRESETS["100m"].param_count()
        assert 80e6 < n < 130e6, n


class TestTraining:
    def test_loss_decreases_overfitting(self):
        params = params_for(CFG)
        m, v = trn.zero_opt_state(params)
        toks, tgts = tokens_for(CFG)
        step_fn = jax.jit(lambda p, m, v, s, lr: trn.train_step(CFG, p, m, v, s, toks, tgts, lr))
        losses = []
        p = params
        for s in range(1, 21):
            p, m, v, loss, gnorm = step_fn(p, m, v, float(s), 2e-3)
            losses.append(float(loss))
            assert np.isfinite(float(gnorm))
        assert losses[-1] < losses[0] - 1.0, losses[::5]

    def test_grad_clip_bounds_update(self):
        params = params_for(CFG)
        grads = {k: jnp.ones_like(v) * 100.0 for k, v in params.items()}
        m, v = trn.zero_opt_state(params)
        _, _, _, gnorm = trn.adamw_update(params, grads, m, v, 1.0, 1e-3)
        assert float(gnorm) > trn.GRAD_CLIP  # reported pre-clip norm

    def test_masked_positions_do_not_contribute(self):
        params = params_for(CFG)
        toks, tgts = tokens_for(CFG)
        all_masked = -jnp.ones_like(tgts)
        loss = mdl.loss_fn(CFG, params, toks, all_masked)
        assert float(loss) == 0.0

    def test_eval_step_consistency(self):
        params = params_for(CFG)
        toks, tgts = tokens_for(CFG)
        loss_sum, count, correct = trn.eval_step(CFG, params, toks, tgts)
        assert float(count) == 2 * 31  # one masked position per row
        assert 0 <= float(correct) <= float(count)
        loss = mdl.loss_fn(CFG, params, toks, tgts)
        np.testing.assert_allclose(float(loss_sum) / float(count), float(loss), rtol=1e-5)

    def test_cosine_lr_mirror(self):
        # python mirror == rust mirror semantics (sanity of the contract)
        lr0 = trn.cosine_lr(0.0, 3e-4, 100.0, 1000.0, 3e-5)
        lr_peak = trn.cosine_lr(100.0, 3e-4, 100.0, 1000.0, 3e-5)
        lr_end = trn.cosine_lr(1000.0, 3e-4, 100.0, 1000.0, 3e-5)
        assert lr0 == 0.0
        assert abs(lr_peak - 3e-4) < 1e-9
        assert abs(lr_end - 3e-5) < 1e-9


class TestServingParity:
    def test_prefill_then_decode_equals_forward(self):
        params = params_for(CFG, seed=3)
        toks, _ = tokens_for(CFG, b=4, l=33, seed=5)
        # prefill on the first 32, decode token 32
        logits_pf, state = mdl.prefill(CFG, params, toks[:, :32])
        full32 = mdl.forward(CFG, params, toks[:, :32])[:, -1]
        np.testing.assert_allclose(logits_pf, full32, atol=1e-4)
        logits_dec, state = mdl.decode_step(CFG, params, state, toks[:, 32])
        full33 = mdl.forward(CFG, params, toks[:, :33])[:, -1]
        np.testing.assert_allclose(logits_dec, full33, atol=1e-4)

    def test_pure_decode_from_zero_state_matches_forward(self):
        params = params_for(CFG, seed=4)
        toks, _ = tokens_for(CFG, b=2, l=8, seed=6)
        state = mdl.zero_decode_state(CFG, 2)
        for t in range(8):
            logits, state = mdl.decode_step(CFG, params, state, toks[:, t])
        full = mdl.forward(CFG, params, toks)[:, -1]
        np.testing.assert_allclose(logits, full, atol=1e-4)

    def test_decode_state_shapes_stable(self):
        params = params_for(CFG)
        state = mdl.zero_decode_state(CFG, 2)
        shapes0 = {k: v.shape for k, v in state.items()}
        tok = jnp.zeros((2,), jnp.int32)
        _, state = mdl.decode_step(CFG, params, state, tok)
        assert {k: v.shape for k, v in state.items()} == shapes0


class TestClassifier:
    def test_forward_and_train(self):
        cfg = clf.ClassifierConfig(n_layers=1)
        params = clf.init_params(jax.random.PRNGKey(0), cfg)
        key = jax.random.PRNGKey(1)
        px = jax.random.uniform(key, (4, clf.SEQ_LEN))
        labels = jnp.array([0, 3, 7, 9], jnp.int32)
        logits = clf.forward(cfg, params, px)
        assert logits.shape == (4, 10)
        m, v = trn.zero_opt_state(params)
        step_fn = jax.jit(
            lambda p, m, v, s: clf.train_step(cfg, p, m, v, s, px, labels, 3e-3)
        )
        losses = []
        p = params
        for s in range(1, 16):
            p, m, v, loss, _ = step_fn(p, m, v, float(s))
            losses.append(float(loss))
        assert losses[-1] < losses[0], losses

    def test_deltanet_zero_pixel_rows_have_finite_grads(self):
        # Regression: dark pixel runs (common in sMNIST) make some tokens'
        # keys exactly zero; l2_normalize must not produce 0 * inf = NaN in
        # the backward pass (sqrt-then-clamp did; rsqrt-of-clamped doesn't).
        cfg = clf.ClassifierConfig(n_layers=1, mixer="deltanet")
        params = clf.init_params(jax.random.PRNGKey(0), cfg)
        px = jnp.zeros((2, clf.SEQ_LEN))  # all-dark images: worst case
        labels = jnp.array([0, 1], jnp.int32)
        g = jax.grad(lambda p: clf.loss_fn(cfg, p, px, labels))(params)
        for k, v in g.items():
            assert bool(jnp.all(jnp.isfinite(v))), f"non-finite grad in {k}"

    def test_eval_step_counts(self):
        cfg = clf.ClassifierConfig(n_layers=1)
        params = clf.init_params(jax.random.PRNGKey(0), cfg)
        px = jnp.zeros((4, clf.SEQ_LEN))
        labels = jnp.array([1, 2, 3, 4], jnp.int32)
        loss_sum, correct = clf.eval_step(cfg, params, px, labels)
        assert float(loss_sum) > 0
        assert 0 <= float(correct) <= 4
