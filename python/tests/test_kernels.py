"""L1 kernel correctness: Pallas chunkwise kernel vs pure-jnp oracles.

The CORE correctness signal of the repo: every member of the integrator
family, every chunk size, every shape — against the sequential scan oracle,
the quadratic unrolled oracle, and each other.
"""

import jax
import jax.numpy as jnp
import numpy as np
import pytest
from hypothesis import given, settings, strategies as st

from compile.kernels import (
    alpha_efla,
    alpha_euler,
    alpha_rk,
    chunkwise_delta,
    chunkwise_delta_reference,
    deltanet_attention,
    efla_attention,
    efla_recurrent_step,
    l2_normalize,
    naive_quadratic_delta,
    sequential_delta_with_state,
)
from compile.kernels.gates import EPS_LAMBDA, gate_series

TOL = 5e-5


def make_inputs(seed, b, h, l, dk, dv, k_scale=0.7):
    ks = jax.random.split(jax.random.PRNGKey(seed), 4)
    q = jax.random.normal(ks[0], (b, h, l, dk), jnp.float32)
    k = jax.random.normal(ks[1], (b, h, l, dk), jnp.float32) * k_scale
    v = jax.random.normal(ks[2], (b, h, l, dv), jnp.float32)
    beta = jax.nn.sigmoid(jax.random.normal(ks[3], (b, h, l), jnp.float32))
    return q, k, v, beta


def efla_alpha(k, beta):
    lam = jnp.sum(jnp.square(k), -1)
    return alpha_efla(beta, lam)


class TestChunkwiseKernel:
    def test_matches_sequential_oracle(self):
        q, k, v, beta = make_inputs(0, 2, 3, 100, 16, 16)
        alpha = efla_alpha(k, beta)
        o_pl, s_pl = chunkwise_delta(q, k, v, alpha, chunk=32)
        o_seq, s_seq = sequential_delta_with_state(q, k, v, alpha)
        np.testing.assert_allclose(o_pl, o_seq, atol=1e-4)
        np.testing.assert_allclose(s_pl, s_seq, atol=1e-4)

    def test_matches_jnp_chunkwise_reference(self):
        q, k, v, beta = make_inputs(1, 1, 2, 64, 8, 8)
        alpha = efla_alpha(k, beta)
        o_pl, s_pl = chunkwise_delta(q, k, v, alpha, chunk=16)
        o_ref, s_ref = chunkwise_delta_reference(q, k, v, alpha, chunk=16)
        np.testing.assert_allclose(o_pl, o_ref, atol=TOL)
        np.testing.assert_allclose(s_pl, s_ref, atol=TOL)

    def test_matches_quadratic_oracle(self):
        q, k, v, beta = make_inputs(2, 1, 1, 24, 6, 6)
        alpha = efla_alpha(k, beta)
        o_pl, _ = chunkwise_delta(q, k, v, alpha, chunk=8)
        o_naive = naive_quadratic_delta(q, k, v, alpha)
        np.testing.assert_allclose(o_pl, o_naive, atol=1e-4)

    @pytest.mark.parametrize("chunk", [1, 4, 16, 64])
    def test_chunk_size_invariance(self, chunk):
        q, k, v, beta = make_inputs(3, 1, 2, 96, 8, 8)
        alpha = efla_alpha(k, beta)
        o_c, s_c = chunkwise_delta(q, k, v, alpha, chunk=chunk)
        o_1, s_1 = chunkwise_delta(q, k, v, alpha, chunk=32)
        np.testing.assert_allclose(o_c, o_1, atol=1e-4)
        np.testing.assert_allclose(s_c, s_1, atol=1e-4)

    def test_large_chunk_accumulates_bounded_f32_error(self):
        # The UT-transform inverse's entries grow with C, so f32 error grows
        # too — this pins that C=128 stays within engineering tolerance (and
        # documents why production uses C<=64, as in the DeltaNet kernels).
        q, k, v, beta = make_inputs(3, 1, 2, 96, 8, 8)
        alpha = efla_alpha(k, beta)
        o_c, _ = chunkwise_delta(q, k, v, alpha, chunk=128)
        o_1, _ = chunkwise_delta(q, k, v, alpha, chunk=32)
        np.testing.assert_allclose(o_c, o_1, atol=2e-2)

    def test_ragged_length_padding_is_exact(self):
        # L=77 not divisible by 16: padding tokens must be exact no-ops.
        q, k, v, beta = make_inputs(4, 1, 1, 77, 8, 8)
        alpha = efla_alpha(k, beta)
        o_pl, s_pl = chunkwise_delta(q, k, v, alpha, chunk=16)
        o_seq, s_seq = sequential_delta_with_state(q, k, v, alpha)
        np.testing.assert_allclose(o_pl, o_seq, atol=TOL)
        np.testing.assert_allclose(s_pl, s_seq, atol=TOL)

    def test_initial_state_continuation(self):
        # Split a sequence in two; second half with s0 = first half's state
        # must equal the unsplit run.
        q, k, v, beta = make_inputs(5, 1, 2, 64, 8, 8)
        alpha = efla_alpha(k, beta)
        o_full, s_full = chunkwise_delta(q, k, v, alpha, chunk=16)
        o_a, s_a = chunkwise_delta(
            q[:, :, :32], k[:, :, :32], v[:, :, :32], alpha[:, :, :32], chunk=16
        )
        o_b, s_b = chunkwise_delta(
            q[:, :, 32:], k[:, :, 32:], v[:, :, 32:], alpha[:, :, 32:],
            s0=s_a, chunk=16,
        )
        np.testing.assert_allclose(o_a, o_full[:, :, :32], atol=TOL)
        np.testing.assert_allclose(o_b, o_full[:, :, 32:], atol=1e-4)
        np.testing.assert_allclose(s_b, s_full, atol=1e-4)

    def test_dtype_bfloat16_inputs(self):
        q, k, v, beta = make_inputs(6, 1, 1, 32, 8, 8)
        qb = q.astype(jnp.bfloat16)
        kb = k.astype(jnp.bfloat16)
        vb = v.astype(jnp.bfloat16)
        alpha = efla_alpha(kb.astype(jnp.float32), beta)
        o_b, s_b = chunkwise_delta(qb, kb, vb, alpha, chunk=8)
        assert o_b.dtype == jnp.bfloat16
        assert s_b.dtype == jnp.float32  # state accumulates in f32
        o_f, _ = chunkwise_delta(
            qb.astype(jnp.float32), kb.astype(jnp.float32), vb.astype(jnp.float32),
            alpha, chunk=8,
        )
        np.testing.assert_allclose(
            o_b.astype(jnp.float32), o_f, atol=0.15, rtol=0.1
        )

    def test_zero_alpha_is_identity(self):
        q, k, v, beta = make_inputs(7, 1, 1, 32, 8, 8)
        alpha = jnp.zeros_like(beta)
        o, s = chunkwise_delta(q, k, v, alpha, chunk=8)
        assert float(jnp.abs(o).max()) == 0.0
        assert float(jnp.abs(s).max()) == 0.0

    def test_stiff_positive_key_regime_no_overflow(self):
        # Regression: silu-activated (all-positive, correlated) unnormalized
        # keys — EFLA's production regime — make every entry of the in-chunk
        # matrix A positive and O(1), so a whole-chunk doubling inverse
        # materializes A^{2^i} with norms ~ entry^C and overflows f32 at
        # C >= ~48. The blocked forward-substitution solve must stay exact.
        ks = jax.random.split(jax.random.PRNGKey(55), 4)
        q = jax.nn.silu(jax.random.normal(ks[0], (1, 2, 112, 16)))
        k = jax.nn.silu(jax.random.normal(ks[1], (1, 2, 112, 16))) * 1.5
        v = jax.random.normal(ks[2], (1, 2, 112, 16))
        beta = jax.nn.sigmoid(jax.random.normal(ks[3], (1, 2, 112)))
        alpha = efla_alpha(k, beta)
        o_pl, s_pl = chunkwise_delta(q, k, v, alpha, chunk=56)
        o_seq, s_seq = sequential_delta_with_state(q, k, v, alpha)
        assert bool(jnp.all(jnp.isfinite(o_pl)))
        np.testing.assert_allclose(o_pl, o_seq, atol=1e-4)
        np.testing.assert_allclose(s_pl, s_seq, atol=1e-4)

    def test_gradients_flow_and_match_reference(self):
        q, k, v, beta = make_inputs(8, 1, 1, 32, 8, 8)

        def loss_pallas(q, k, v, beta):
            alpha = efla_alpha(k, beta)
            o, s = chunkwise_delta(q, k, v, alpha, chunk=8)
            return jnp.sum(o * o) + jnp.sum(s)

        def loss_ref(q, k, v, beta):
            alpha = efla_alpha(k, beta)
            o, s = chunkwise_delta_reference(q, k, v, alpha, chunk=8)
            return jnp.sum(o * o) + jnp.sum(s)

        g_pl = jax.grad(loss_pallas, argnums=(0, 1, 2, 3))(q, k, v, beta)
        g_rf = jax.grad(loss_ref, argnums=(0, 1, 2, 3))(q, k, v, beta)
        for a, b in zip(g_pl, g_rf):
            assert jnp.all(jnp.isfinite(a))
            np.testing.assert_allclose(a, b, atol=1e-3, rtol=1e-3)


class TestPublicAttentionApis:
    def test_efla_uses_exact_gate(self):
        q, k, v, beta = make_inputs(10, 2, 2, 48, 8, 8)
        o1, s1 = efla_attention(q, k, v, beta, chunk=16)
        alpha = efla_alpha(k, beta)
        o2, s2 = sequential_delta_with_state(q, k, v, alpha)
        np.testing.assert_allclose(o1, o2, atol=1e-4)
        np.testing.assert_allclose(s1, s2, atol=1e-4)

    def test_deltanet_normalizes_keys(self):
        q, k, v, beta = make_inputs(11, 1, 2, 48, 8, 8, k_scale=3.0)
        o1, _ = deltanet_attention(q, k, v, beta, chunk=16)
        qn, kn = l2_normalize(q), l2_normalize(k)
        o2, _ = sequential_delta_with_state(qn, kn, v, beta)
        np.testing.assert_allclose(o1, o2, atol=1e-4)

    def test_recurrent_step_matches_sequence(self):
        q, k, v, beta = make_inputs(12, 2, 2, 12, 8, 8)
        o_seq, _ = efla_attention(q, k, v, beta, chunk=4)
        s = jnp.zeros((2, 2, 8, 8), jnp.float32)
        for t in range(12):
            o_t, s = efla_recurrent_step(s, q[:, :, t], k[:, :, t], v[:, :, t], beta[:, :, t])
            np.testing.assert_allclose(o_t, o_seq[:, :, t], atol=1e-4)

    def test_efla_bounded_under_huge_keys_where_deltanet_unstable(self):
        # paper §5.1: high-energy inputs. EFLA state stays bounded without
        # normalization; raw Euler (unnormalized deltanet) explodes.
        q, k, v, beta = make_inputs(13, 1, 1, 64, 8, 8, k_scale=5.0)
        o_efla, s_efla = efla_attention(q, k, v, beta, chunk=16)
        assert bool(jnp.all(jnp.isfinite(o_efla)))
        assert float(jnp.abs(s_efla).max()) < 1e3
        o_euler, s_euler = sequential_delta_with_state(q, k, v, beta)  # alpha=beta
        assert (not bool(jnp.all(jnp.isfinite(s_euler)))) or float(
            jnp.abs(s_euler).max()
        ) > 1e4


class TestGates:
    def test_rk1_is_euler(self):
        x = jnp.linspace(0, 5, 11)
        np.testing.assert_allclose(alpha_rk(x, jnp.ones_like(x), 1), x, atol=1e-6)
        np.testing.assert_allclose(alpha_euler(x), x)

    def test_gate_series_converges_to_expm1(self):
        x = jnp.linspace(0.0, 4.0, 9)
        g30 = gate_series(x, 30)
        np.testing.assert_allclose(g30, jnp.expm1(-x), atol=1e-6)

    def test_alpha_efla_small_lambda_limit(self):
        beta = jnp.asarray([0.3, 0.9])
        lam = jnp.asarray([1e-10, 1e-9])
        np.testing.assert_allclose(alpha_efla(beta, lam), beta, atol=1e-6)

    def test_alpha_efla_eigenvalue_bound(self):
        beta = jnp.linspace(0.0, 3.0, 7)[None]
        lam = jnp.logspace(-6, 3, 10)[:, None]
        ev = 1.0 - alpha_efla(beta, lam) * lam
        assert bool(jnp.all(ev >= -1e-6))
        assert bool(jnp.all(ev <= 1.0 + 1e-6))
        np.testing.assert_allclose(ev, jnp.exp(-beta * lam), atol=2e-5)

    def test_order_convergence_is_monotone(self):
        beta, lam = 0.8, 2.5  # x = beta*lambda = 2: needs order ~16 for 1e-5
        exact = float(alpha_efla(jnp.float32(beta), jnp.float32(lam)))
        errs = [
            abs(float(alpha_rk(jnp.float32(beta), jnp.float32(lam), n)) - exact)
            for n in (1, 2, 4, 8, 16)
        ]
        assert all(errs[i + 1] <= errs[i] + 1e-7 for i in range(len(errs) - 1))
        assert errs[-1] < 1e-5


@settings(max_examples=10, deadline=None)
@given(
    b=st.integers(1, 2),
    h=st.integers(1, 3),
    l=st.integers(1, 70),
    dk=st.sampled_from([2, 4, 8, 16]),
    dv=st.sampled_from([2, 4, 8, 16]),
    chunk=st.sampled_from([1, 3, 8, 16, 64]),
    seed=st.integers(0, 2**16),
)
def test_hypothesis_chunkwise_matches_sequential(b, h, l, dk, dv, chunk, seed):
    """Property sweep: arbitrary shapes/chunks, Pallas == sequential oracle."""
    q, k, v, beta = make_inputs(seed, b, h, l, dk, dv)
    alpha = efla_alpha(k, beta)
    o_pl, s_pl = chunkwise_delta(q, k, v, alpha, chunk=chunk)
    o_seq, s_seq = sequential_delta_with_state(q, k, v, alpha)
    np.testing.assert_allclose(o_pl, o_seq, atol=2e-4)
    np.testing.assert_allclose(s_pl, s_seq, atol=2e-4)


@settings(max_examples=10, deadline=None)
@given(
    beta=st.floats(0.0, 4.0),
    lam=st.floats(1e-8, 1e4),
)
def test_hypothesis_gate_invariants(beta, lam):
    """EFLA gate: 0 <= alpha <= beta; eigenvalue in [0, 1]; expm1 precision."""
    a = float(alpha_efla(jnp.float32(beta), jnp.float32(lam)))
    assert 0.0 <= a <= beta + 1e-5
    ev = 1.0 - a * lam
    assert -1e-4 <= ev <= 1.0 + 1e-5
