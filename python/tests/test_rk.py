"""Runge-Kutta family: stage-form == gate-form, order convergence, and the
error-accumulation analysis behind paper §3/§6."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from compile.kernels.rk import exact_integrate, rk_integrate, rk_stage_integrate
from compile.kernels.ref import sequential_delta_with_state
from compile.kernels.gates import alpha_efla


def make(seed, l=48, d=8, k_scale=0.25):
    ks = jax.random.split(jax.random.PRNGKey(seed), 4)
    q = jax.random.normal(ks[0], (1, 2, l, d), jnp.float32)
    k = jax.random.normal(ks[1], (1, 2, l, d), jnp.float32) * k_scale
    v = jax.random.normal(ks[2], (1, 2, l, d), jnp.float32)
    beta = jax.nn.sigmoid(jax.random.normal(ks[3], (1, 2, l), jnp.float32))
    return q, k, v, beta


class TestStageGateEquivalence:
    """The collapsed scalar gate (Appendix D) is EXACTLY the multi-stage RK
    update for the rank-1 linear ODE — per order."""

    @pytest.mark.parametrize("order", [1, 2, 4])
    def test_stage_equals_gate(self, order):
        q, k, v, beta = make(order)
        o_gate, s_gate = rk_integrate(q, k, v, beta, order)
        o_stage, s_stage = rk_stage_integrate(q, k, v, beta, order)
        np.testing.assert_allclose(o_gate, o_stage, atol=1e-4)
        np.testing.assert_allclose(s_gate, s_stage, atol=1e-4)

    def test_unsupported_order_raises(self):
        q, k, v, beta = make(0, l=4)
        with pytest.raises(ValueError):
            rk_stage_integrate(q, k, v, beta, 3)


class TestOrderConvergence:
    def test_error_vs_exact_decreases_with_order(self):
        q, k, v, beta = make(7, l=64, d=8, k_scale=0.35)
        o_exact, _ = exact_integrate(q, k, v, beta)
        errs = []
        for order in (1, 2, 4):
            o_n, _ = rk_integrate(q, k, v, beta, order)
            errs.append(float(jnp.abs(o_n - o_exact).max()))
        assert errs[0] > errs[1] > errs[2], errs
        # absolute error accumulates over L=64 tokens (occasional stiff
        # tokens dominate the max); order-4 must still clearly beat Euler
        assert errs[2] < errs[0] / 3.0, errs

    def test_exact_equals_efla_gate(self):
        q, k, v, beta = make(9)
        o1, s1 = exact_integrate(q, k, v, beta)
        lam = jnp.sum(k * k, -1)
        o2, s2 = sequential_delta_with_state(q, k, v, alpha_efla(beta, lam))
        np.testing.assert_allclose(o1, o2, atol=1e-6)
        np.testing.assert_allclose(s1, s2, atol=1e-6)

    def test_euler_error_grows_with_sequence_length(self):
        # error ACCUMULATION: Euler drifts further from exact as L grows.
        q, k, v, beta = make(11, l=128, d=8, k_scale=0.4)
        o_exact, _ = exact_integrate(q, k, v, beta)
        o_euler, _ = rk_integrate(q, k, v, beta, 1)
        err = jnp.abs(o_euler - o_exact).max(axis=(0, 1, 3))  # per position
        # compare mean error in the first vs last quarter
        first = float(err[:32].mean())
        last = float(err[-32:].mean())
        assert last > first, (first, last)


class TestStabilityRegimes:
    def test_euler_unstable_efla_stable_at_high_stiffness(self):
        q, k, v, beta = make(13, l=96, d=8, k_scale=3.0)  # beta*lambda >> 2
        _, s_euler = rk_integrate(q, k, v, beta, 1)
        _, s_exact = exact_integrate(q, k, v, beta)
        euler_norm = float(jnp.abs(s_euler).max())
        exact_norm = float(jnp.abs(s_exact).max())
        assert euler_norm > 1e4 or not np.isfinite(euler_norm)
        assert exact_norm < 1e3
