"""Fig-1/Fig-2 substrate: the sMNIST Linear Attention Classifier (paper §5.1).

Pixel-level sequential MNIST: 28x28 images flattened to L=784 scalar pixels,
a linear projection into d=64, ``n_layers`` mixer blocks (EFLA or DeltaNet —
same blocks as the LM, minus vocabulary), mean pooling, 10-way head.

The corruption operators (dropout / intensity scaling / additive Gaussian
noise) are applied by the Rust data pipeline *to the raw pixel sequences*, so
these graphs are corruption-agnostic.
"""

import dataclasses
from collections import OrderedDict

import jax
import jax.numpy as jnp

from .model import (
    CONV_K,
    ModelConfig,
    causal_conv,
    mixer_forward,
    mlp_forward,
    rms_norm,
)
from .train import adamw_update

N_CLASSES = 10
SEQ_LEN = 784


@dataclasses.dataclass(frozen=True)
class ClassifierConfig:
    d_model: int = 64
    n_layers: int = 2
    n_heads: int = 2
    head_dim: int = 32
    mlp_mult: int = 4
    chunk: int = 56  # 784 = 14 * 56; avoids padding the full sequence
    mixer: str = "efla"
    norm_eps: float = 1e-6

    def to_model_config(self) -> ModelConfig:
        return ModelConfig(
            vocab=1,  # unused; classifier embeds pixels linearly
            d_model=self.d_model,
            n_layers=self.n_layers,
            n_heads=self.n_heads,
            head_dim=self.head_dim,
            mlp_mult=self.mlp_mult,
            chunk=self.chunk,
            mixer=self.mixer,
            norm_eps=self.norm_eps,
        )


def _param_specs(cfg: ClassifierConfig):
    d, inner, h = cfg.d_model, cfg.n_heads * cfg.head_dim, cfg.n_heads
    yield "pix_w", (1, d), "normal"
    yield "pix_b", (d,), "zeros"
    for i in range(cfg.n_layers):
        p = f"layer{i}."
        yield p + "norm_attn", (d,), "ones"
        yield p + "wq", (d, inner), "normal"
        yield p + "wk", (d, inner), "normal"
        yield p + "wv", (d, inner), "normal"
        yield p + "conv_q", (CONV_K, inner), "conv"
        yield p + "conv_k", (CONV_K, inner), "conv"
        yield p + "conv_v", (CONV_K, inner), "conv"
        yield p + "w_beta", (d, h), "normal"
        yield p + "adecay", (h,), "zeros"
        yield p + "norm_out", (cfg.head_dim,), "ones"
        yield p + "wo", (inner, d), "normal"
        yield p + "norm_mlp", (d,), "ones"
        yield p + "w_gate", (d, cfg.mlp_mult * d), "normal"
        yield p + "w_up", (d, cfg.mlp_mult * d), "normal"
        yield p + "w_down", (cfg.mlp_mult * d, d), "normal"
    yield "norm_f", (d,), "ones"
    yield "head_w", (d, N_CLASSES), "normal"
    yield "head_b", (N_CLASSES,), "zeros"


def init_params(key, cfg: ClassifierConfig, abstract: bool = False):
    params = OrderedDict()
    specs = list(_param_specs(cfg))
    keys = jax.random.split(key, len(specs))
    for (name, shape, kind), k in zip(specs, keys):
        if abstract:
            params[name] = jax.ShapeDtypeStruct(shape, jnp.float32)
            continue
        if kind == "normal":
            params[name] = jax.random.normal(k, shape, jnp.float32) * (shape[0] ** -0.5)
        elif kind == "conv":
            w = jax.random.normal(k, shape, jnp.float32) * 0.02
            params[name] = w.at[-1].add(1.0)
        elif kind == "ones":
            params[name] = jnp.ones(shape, jnp.float32)
        else:
            params[name] = jnp.zeros(shape, jnp.float32)
    return params


def forward(cfg: ClassifierConfig, params, pixels):
    """pixels: (B, 784) float32 -> logits (B, 10)."""
    mcfg = cfg.to_model_config()
    x = pixels[..., None] @ params["pix_w"] + params["pix_b"]  # (B, L, D)
    for i in range(cfg.n_layers):
        p = f"layer{i}."
        h = rms_norm(x, params[p + "norm_attn"], cfg.norm_eps)
        mixed, _ = mixer_forward(mcfg, params, p, h)
        x = x + mixed
        h = rms_norm(x, params[p + "norm_mlp"], cfg.norm_eps)
        x = x + mlp_forward(mcfg, params, p, h)
    x = rms_norm(jnp.mean(x, axis=1), params["norm_f"], cfg.norm_eps)
    return x @ params["head_w"] + params["head_b"]


def loss_fn(cfg: ClassifierConfig, params, pixels, labels):
    logits = forward(cfg, params, pixels)
    logp = jax.nn.log_softmax(logits, axis=-1)
    nll = -jnp.take_along_axis(logp, labels[:, None], axis=-1)[:, 0]
    return nll.mean()


def train_step(cfg: ClassifierConfig, params, m, v, step, pixels, labels, lr):
    """Returns (params', m', v', loss, gnorm). pixels (B,784) f32, labels (B,) i32."""
    loss, grads = jax.value_and_grad(lambda p: loss_fn(cfg, p, pixels, labels))(params)
    new_p, new_m, new_v, gnorm = adamw_update(params, grads, m, v, step, lr)
    return new_p, new_m, new_v, loss, gnorm


def eval_step(cfg: ClassifierConfig, params, pixels, labels):
    """Returns (loss_sum, correct_count) over the batch."""
    logits = forward(cfg, params, pixels)
    logp = jax.nn.log_softmax(logits, axis=-1)
    nll = -jnp.take_along_axis(logp, labels[:, None], axis=-1)[:, 0]
    correct = (jnp.argmax(logits, axis=-1) == labels).astype(jnp.float32)
    return nll.sum(), correct.sum()
