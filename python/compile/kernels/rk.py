"""Literal Runge-Kutta integrators of the delta-rule ODE (Appendix F).

Two redundant implementations on purpose:

  * ``rk_integrate``       — the collapsed scalar-gate form (alpha_N from
                             ``gates.py``) run through the sequential oracle;
  * ``rk_stage_integrate`` — the *textbook multi-stage* RK scheme computing
                             slope matrices k_1..k_s on full (Dk, Dv) states.

Their agreement (pytest ``test_rk_stage_equivalence``) validates the algebra
that lets one chunkwise kernel serve the whole family; their disagreement
with ``exact_integrate`` as order decreases reproduces the paper's
error-accumulation analysis (bench ``kernel_throughput`` error sweep).
"""

import jax
import jax.numpy as jnp

from .gates import alpha_rk, alpha_efla
from .ref import sequential_delta_with_state

# Butcher tableaus (explicit): (a_lower_rows, b_weights, c_nodes)
_TABLEAUS = {
    1: ([], [1.0], [0.0]),
    2: ([[0.5]], [0.0, 1.0], [0.0, 0.5]),  # midpoint, matches Appendix F RK-2
    4: (
        [[0.5], [0.0, 0.5], [0.0, 0.0, 1.0]],
        [1.0 / 6, 1.0 / 3, 1.0 / 3, 1.0 / 6],
        [0.0, 0.5, 0.5, 1.0],
    ),
}


def rk_integrate(q, k, v, beta, order: int, s0=None):
    """Order-N RK via the collapsed gate alpha_N (paper Eq. 13 + Appendix D)."""
    lam = jnp.sum(jnp.square(k.astype(jnp.float32)), axis=-1)
    alpha = alpha_rk(beta.astype(jnp.float32), lam, order)
    return sequential_delta_with_state(q, k, v, alpha, s0)


def exact_integrate(q, k, v, beta, s0=None):
    """RK-inf / exact ODE solution == EFLA, via the sequential oracle."""
    lam = jnp.sum(jnp.square(k.astype(jnp.float32)), axis=-1)
    alpha = alpha_efla(beta.astype(jnp.float32), lam)
    return sequential_delta_with_state(q, k, v, alpha, s0)


def rk_stage_integrate(q, k, v, beta, order: int, s0=None):
    """Textbook multi-stage explicit RK on  dS/dt = -k k^T S + k v^T.

    Stage slopes are full (B, H, Dk, Dv) matrices:
        f(S) = -k (k^T S) + k v^T          (ZOH: k, v frozen within the step)
        g_i  = f(S + beta * sum_j a_ij g_j)
        S'   = S + beta * sum_i b_i g_i
        o_t  = S'^T q_t
    """
    if order not in _TABLEAUS:
        raise ValueError(f"no tableau for order {order}; have {sorted(_TABLEAUS)}")
    a_rows, b_w, _ = _TABLEAUS[order]

    bsz, h, l, dk = q.shape
    dv = v.shape[-1]
    qf = q.astype(jnp.float32)
    kf = k.astype(jnp.float32)
    vf = v.astype(jnp.float32)
    bf = beta.astype(jnp.float32)
    if s0 is None:
        s0 = jnp.zeros((bsz, h, dk, dv), jnp.float32)

    def f(s, kt, vt):
        stk = jnp.einsum("bhkv,bhk->bhv", s, kt)
        return jnp.einsum("bhk,bhv->bhkv", kt, vt - stk)

    def step(s, inp):
        qt, kt, vt, bt = inp
        bt_ = bt[..., None, None]
        slopes = []
        for i in range(order):
            si = s
            for j, aij in enumerate(a_rows[i - 1] if i > 0 else []):
                if aij != 0.0:
                    si = si + bt_ * aij * slopes[j]
            slopes.append(f(si, kt, vt))
        s_new = s
        for bi, gi in zip(b_w, slopes):
            if bi != 0.0:
                s_new = s_new + bt_ * bi * gi
        o = jnp.einsum("bhkv,bhk->bhv", s_new, qt)
        return s_new, o

    xs = (
        jnp.moveaxis(qf, 2, 0),
        jnp.moveaxis(kf, 2, 0),
        jnp.moveaxis(vf, 2, 0),
        jnp.moveaxis(bf, 2, 0),
    )
    s_final, outs = jax.lax.scan(step, s0, xs)
    return jnp.moveaxis(outs, 0, 2).astype(q.dtype), s_final
