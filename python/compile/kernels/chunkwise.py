"""Chunkwise-parallel generalized delta rule as a Pallas kernel (Eqs. 21-32).

One kernel serves DeltaNet, RK-N and EFLA: the integrator order is entirely
absorbed into the per-token scalar gate ``alpha`` computed upstream (see
``gates.py``).  The kernel implements the WY representation + UT transform of
Yang et al. 2024b, which the paper shows carries over to EFLA unchanged:

    per chunk of size C, with A = strict_tril(diag(alpha) K K^T):
      T  = (I + A)^{-1} diag(alpha)          (UT transform, Eq. 31)
      W  = T K,   U = T V                    (Eq. 32)
      O  = Q S + (tril(Q K^T)) (U - W S)     (Eq. 30)
      S' = S + K^T (U - W S)                 (Eq. 29)

TPU adaptation (DESIGN.md §Hardware-Adaptation):
  * grid = (B*H, L/C); the chunk axis is the sequential ("arbitrary") grid
    dimension and the running state S (Dk x Dv, f32) lives in a VMEM scratch
    accumulator across chunk steps — the Triton original round-trips S through
    HBM between thread-block launches.
  * the (I + A)^{-1} forward-substitution of the Triton kernel is replaced by
    an exact *nilpotent doubling* product — A is strictly lower triangular so
    A^C = 0 and (I+A)^{-1} = prod_{i<m} (I + (-A)^{2^i}) with 2^m >= C: that
    is ceil(log2 C) dense CxC matmuls, which map onto the MXU instead of a
    C-step scalar-dependency chain.
  * all matmuls accumulate in float32 via ``preferred_element_type`` —
    bf16-safe inputs, f32 state, matching the paper's training setup.

Pallas runs with interpret=True everywhere in this repo: the CPU PJRT client
cannot execute Mosaic custom-calls, and correctness (not wallclock) is what
the interpret path certifies.  BlockSpecs are still written exactly as a real
TPU lowering would want them.
"""

import functools
import math

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

DEFAULT_CHUNK = 64


def _inv_unit_lower_batched(a_strict, c):
    """Exact inverse of (I + A), A strictly lower triangular (nilpotent),
    via the doubling product (I+A)^{-1} = prod_i (I + G^{2^i}), G = -A.

    ceil(log2 C) dense matmuls (MXU-shaped, differentiable, no LAPACK
    custom-call — the AOT runtime cannot execute those). Only safe for
    SMALL C: see ``_solve_unit_lower`` for why and for the blocked form
    used on full chunks. Accepts any (..., C, C) batching."""
    eye = jnp.eye(c, dtype=a_strict.dtype)
    g = -a_strict
    p = eye + g
    steps = max(1, math.ceil(math.log2(c))) if c > 1 else 0
    for _ in range(1, steps):
        g = g @ g
        p = p @ (eye + g)
    return p


SOLVE_BLOCK = 8


def _solve_unit_lower(a_strict, rhs, c, block=SOLVE_BLOCK):
    """Solve (I + A) X = rhs, A strictly lower triangular; (..., C, C) @ (..., C, N).

    Numerical-stability note (this bit is load-bearing): the whole-chunk
    doubling inverse materializes A^{2^i}, whose norms grow like
    ``entry_bound^C`` — with unnormalized, positively-correlated keys (silu
    activations; exactly EFLA's regime) that overflows f32 for C >= ~48 even
    though the true solution W/U is benign (the WY recurrence Eq. 25 is
    contractive).  Block forward substitution fixes it: diagonal blocks are
    inverted exactly by doubling at block size (powers stay bounded), and
    the off-diagonal coupling is dense (block x block) matmuls — still
    MXU-shaped work, with a C/block-step dependency chain instead of C.
    """
    if c <= block:
        return _inv_unit_lower_batched(a_strict, c) @ rhs
    n_blocks = math.ceil(c / block)
    xs = []
    for i in range(n_blocks):
        lo, hi = i * block, min(c, (i + 1) * block)
        r = rhs[..., lo:hi, :]
        for j in range(i):
            jlo, jhi = j * block, min(c, (j + 1) * block)
            r = r - a_strict[..., lo:hi, jlo:jhi] @ xs[j]
        inv_ii = _inv_unit_lower_batched(a_strict[..., lo:hi, lo:hi], hi - lo)
        xs.append(inv_ii @ r)
    return jnp.concatenate(xs, axis=-2)


def _chunk_kernel(q_ref, k_ref, v_ref, a_ref, s0_ref, o_ref, sout_ref, s_ref, *, nc, c):
    """One (head, chunk) grid step. s_ref: (Dk, Dv) f32 VMEM accumulator."""
    j = pl.program_id(1)

    @pl.when(j == 0)
    def _init():
        s_ref[...] = s0_ref[0].astype(jnp.float32)

    q = q_ref[0].astype(jnp.float32)  # (C, Dk)
    k = k_ref[0].astype(jnp.float32)  # (C, Dk)
    v = v_ref[0].astype(jnp.float32)  # (C, Dv)
    a = a_ref[0].astype(jnp.float32)  # (C,)
    s = s_ref[...]  # (Dk, Dv)

    # Strictly-lower-triangular masked  diag(a) K K^T  (Eq. 31).
    kk = jnp.dot(k, k.T, preferred_element_type=jnp.float32)  # (C, C)
    rows = jax.lax.broadcasted_iota(jnp.int32, (c, c), 0)
    cols = jax.lax.broadcasted_iota(jnp.int32, (c, c), 1)
    strict = (cols < rows).astype(jnp.float32)
    a_mat = strict * (a[:, None] * kk)

    # W = T K, U = T V with T = (I+A)^{-1} diag(a): fold diag(a) into the
    # right-hand sides and solve both in one blocked forward substitution.
    dk = k.shape[-1]
    rhs = jnp.concatenate([a[:, None] * k, a[:, None] * v], axis=-1)
    wu = _solve_unit_lower(a_mat, rhs, c)
    w, u = wu[:, :dk], wu[:, dk:]

    delta = u - jnp.dot(w, s, preferred_element_type=jnp.float32)  # (C, Dv)

    qk = jnp.dot(q, k.T, preferred_element_type=jnp.float32)
    incl = (cols <= rows).astype(jnp.float32)  # causal, diagonal inclusive
    o = jnp.dot(q, s, preferred_element_type=jnp.float32) + jnp.dot(
        qk * incl, delta, preferred_element_type=jnp.float32
    )

    s_new = s + jnp.dot(k.T, delta, preferred_element_type=jnp.float32)
    s_ref[...] = s_new
    o_ref[0] = o.astype(o_ref.dtype)

    @pl.when(j == nc - 1)
    def _fin():
        sout_ref[0] = s_new.astype(sout_ref.dtype)


def _chunkwise_pallas(q, k, v, alpha, s0, chunk: int):
    """Forward pass via the Pallas kernel (not differentiable on its own)."""
    b, h, l, dk = q.shape
    dv = v.shape[-1]
    c = int(chunk)
    pad = (-l) % c
    if pad:
        q = jnp.pad(q, ((0, 0), (0, 0), (0, pad), (0, 0)))
        k = jnp.pad(k, ((0, 0), (0, 0), (0, pad), (0, 0)))
        v = jnp.pad(v, ((0, 0), (0, 0), (0, pad), (0, 0)))
        alpha = jnp.pad(alpha, ((0, 0), (0, 0), (0, pad)))
    lp = l + pad
    nc = lp // c
    bh = b * h

    qf = q.reshape(bh, lp, dk)
    kf = k.reshape(bh, lp, dk)
    vf = v.reshape(bh, lp, dv)
    af = alpha.reshape(bh, lp)
    sf = s0.reshape(bh, dk, dv).astype(jnp.float32)

    out, s_final = pl.pallas_call(
        functools.partial(_chunk_kernel, nc=nc, c=c),
        grid=(bh, nc),
        in_specs=[
            pl.BlockSpec((1, c, dk), lambda i, j: (i, j, 0)),
            pl.BlockSpec((1, c, dk), lambda i, j: (i, j, 0)),
            pl.BlockSpec((1, c, dv), lambda i, j: (i, j, 0)),
            pl.BlockSpec((1, c), lambda i, j: (i, j)),
            pl.BlockSpec((1, dk, dv), lambda i, j: (i, 0, 0)),
        ],
        out_specs=[
            pl.BlockSpec((1, c, dv), lambda i, j: (i, j, 0)),
            pl.BlockSpec((1, dk, dv), lambda i, j: (i, 0, 0)),
        ],
        out_shape=[
            jax.ShapeDtypeStruct((bh, lp, dv), q.dtype),
            jax.ShapeDtypeStruct((bh, dk, dv), jnp.float32),
        ],
        scratch_shapes=[pltpu.VMEM((dk, dv), jnp.float32)],
        compiler_params=pltpu.CompilerParams(
            dimension_semantics=("parallel", "arbitrary"),
        ),
        interpret=True,
    )(qf, kf, vf, af, sf)

    out = out.reshape(b, h, lp, dv)[:, :, :l]
    return out, s_final.reshape(b, h, dk, dv)


@functools.partial(jax.custom_vjp, nondiff_argnums=(0,))
def _chunkwise_vjp(chunk, q, k, v, alpha, s0):
    return _chunkwise_pallas(q, k, v, alpha, s0, chunk)


def _chunkwise_vjp_fwd(chunk, q, k, v, alpha, s0):
    out = _chunkwise_pallas(q, k, v, alpha, s0, chunk)
    return out, (q, k, v, alpha, s0)


def _chunkwise_vjp_bwd(chunk, res, cotangents):
    """Backward via jax.vjp of the (differentiable) jnp chunkwise reference.

    Forward stays on the Pallas kernel; the backward recomputes the forward
    with the identical-math jnp formulation and lets XLA fuse its transpose.
    EXPERIMENTS.md §Perf tracks the cost of this recompute-in-backward
    choice; a dedicated backward kernel is the documented next optimization.
    """
    q, k, v, alpha, s0 = res
    _, vjp = jax.vjp(
        lambda q_, k_, v_, a_, s_: chunkwise_delta_reference(q_, k_, v_, a_, s0=s_, chunk=chunk),
        q, k, v, alpha, s0,
    )
    return vjp(cotangents)


_chunkwise_vjp.defvjp(_chunkwise_vjp_fwd, _chunkwise_vjp_bwd)


def chunkwise_delta(q, k, v, alpha, s0=None, chunk: int = DEFAULT_CHUNK):
    """Generalized delta-rule attention, chunkwise-parallel Pallas kernel.

    Args:
      q, k:  (B, H, L, Dk);  v: (B, H, L, Dv);  alpha: (B, H, L) scalar gate.
      s0:    optional initial state (B, H, Dk, Dv) — segment continuation /
             recurrent serving prefill.
      chunk: chunk size C; L is zero-padded to a multiple of C (padding uses
             alpha = 0, which is an exact no-op update).

    Differentiable: forward runs the Pallas kernel, backward goes through a
    custom VJP over the jnp reference (identical math).

    Returns ``(out, final_state)`` with ``out: (B, H, L, Dv)`` in the dtype of
    ``q`` and ``final_state: (B, H, Dk, Dv)`` float32.
    """
    b, h, _, dk = q.shape
    dv = v.shape[-1]
    if s0 is None:
        s0 = jnp.zeros((b, h, dk, dv), jnp.float32)
    return _chunkwise_vjp(int(chunk), q, k, v, alpha, s0)


def chunkwise_delta_reference(q, k, v, alpha, s0=None, chunk: int = DEFAULT_CHUNK):
    """Pure-jnp chunkwise form (same math, no Pallas) — a second oracle that
    isolates the WY/UT algebra from the Pallas machinery, and the direct
    template for the Rust mirror in ``rust/src/attention/chunkwise.rs``."""
    b, h, l, dk = q.shape
    dv = v.shape[-1]
    c = int(chunk)
    pad = (-l) % c
    if pad:
        q = jnp.pad(q, ((0, 0), (0, 0), (0, pad), (0, 0)))
        k = jnp.pad(k, ((0, 0), (0, 0), (0, pad), (0, 0)))
        v = jnp.pad(v, ((0, 0), (0, 0), (0, pad), (0, 0)))
        alpha = jnp.pad(alpha, ((0, 0), (0, 0), (0, pad)))
    lp = l + pad
    nc = lp // c

    qf = q.astype(jnp.float32).reshape(b, h, nc, c, dk)
    kf = k.astype(jnp.float32).reshape(b, h, nc, c, dk)
    vf = v.astype(jnp.float32).reshape(b, h, nc, c, dv)
    af = alpha.astype(jnp.float32).reshape(b, h, nc, c)

    eye = jnp.eye(c, dtype=jnp.float32)
    strict = jnp.tril(jnp.ones((c, c), jnp.float32), k=-1)
    incl = jnp.tril(jnp.ones((c, c), jnp.float32))

    if s0 is None:
        s0 = jnp.zeros((b, h, dk, dv), jnp.float32)

    def chunk_step(s, inp):
        qc, kc, vc, ac = inp  # (B,H,C,*)
        kk = jnp.einsum("bhik,bhjk->bhij", kc, kc)
        a_mat = strict * (ac[..., :, None] * kk)
        rhs = jnp.concatenate(
            [ac[..., :, None] * kc, ac[..., :, None] * vc], axis=-1
        )
        wu = _solve_unit_lower(a_mat, rhs, c)
        w, u = wu[..., :dk], wu[..., dk:]
        delta = u - jnp.einsum("bhik,bhkv->bhiv", w, s)
        qk = jnp.einsum("bhik,bhjk->bhij", qc, kc) * incl
        o = jnp.einsum("bhik,bhkv->bhiv", qc, s) + jnp.einsum(
            "bhij,bhjv->bhiv", qk, delta
        )
        s = s + jnp.einsum("bhik,bhiv->bhkv", kc, delta)
        return s, o

    xs = (
        jnp.moveaxis(qf, 2, 0),
        jnp.moveaxis(kf, 2, 0),
        jnp.moveaxis(vf, 2, 0),
        jnp.moveaxis(af, 2, 0),
    )
    s_final, outs = jax.lax.scan(chunk_step, s0, xs)
    out = jnp.moveaxis(outs, 0, 2).reshape(b, h, lp, dv)[:, :, :l]
    return out.astype(q.dtype), s_final
