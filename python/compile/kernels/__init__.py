"""Layer-1 Pallas kernels for Error-Free Linear Attention (EFLA).

The whole integrator family of the paper — Euler (DeltaNet), RK-2, RK-4 and
the exact RK-inf solution (EFLA) — collapses onto ONE generalized delta-rule
recurrence

    S_t = (I - alpha_t k_t k_t^T) S_{t-1} + alpha_t k_t v_t^T

with a per-token scalar gate ``alpha_t`` that depends on the integrator order
(see ``gates.py``).  ``chunkwise.py`` implements that recurrence as a single
hardware-efficient chunkwise-parallel Pallas kernel (WY representation + UT
transform, paper Eqs. 21-32); ``efla.py`` / ``deltanet.py`` are the public
entry points; ``ref.py`` holds the pure-jnp oracles every kernel is tested
against; ``rk.py`` holds the literal multi-stage Runge-Kutta integrators used
to validate the collapsed-gate algebra and to reproduce the error analysis.
"""

from .gates import (
    EPS_LAMBDA,
    alpha_efla,
    alpha_euler,
    alpha_rk,
    gate_series,
)
from .chunkwise import chunkwise_delta, chunkwise_delta_reference
from .efla import efla_attention, efla_recurrent_step
from .deltanet import deltanet_attention, l2_normalize
from .ref import (
    sequential_delta,
    sequential_delta_with_state,
    naive_quadratic_delta,
)
from .rk import rk_integrate, rk_stage_integrate, exact_integrate

__all__ = [
    "EPS_LAMBDA",
    "alpha_efla",
    "alpha_euler",
    "alpha_rk",
    "gate_series",
    "chunkwise_delta",
    "chunkwise_delta_reference",
    "efla_attention",
    "efla_recurrent_step",
    "deltanet_attention",
    "l2_normalize",
    "sequential_delta",
    "sequential_delta_with_state",
    "naive_quadratic_delta",
    "rk_integrate",
    "rk_stage_integrate",
    "exact_integrate",
]
