"""Scalar gates unifying the integrator family (paper §3, Appendix F).

The delta-rule ODE is  dS/dt = -A_t S + b_t  with A_t = k_t k_t^T (rank-1,
eigenvalue lambda_t = ||k_t||^2) and b_t = k_t v_t^T.  Because
A_t^n = lambda_t^{n-1} A_t  (n >= 1, Appendix D) and A_t b_t = lambda_t b_t,
the order-N Runge-Kutta update (paper Eq. 13)

    S_t = [sum_{n=0}^{N} (-beta A)^n / n!] S_{t-1}
        + beta [sum_{n=0}^{N-1} (-beta A)^n / (n+1)!] b_t

collapses to the generalized delta rule

    S_t = (I - alpha_N k k^T) S_{t-1} + alpha_N k v^T,

where, writing x = beta * lambda and  g_N(x) = sum_{m=1}^{N} (-x)^m / m!,

    alpha_N = -g_N(x) / lambda.

Checks:  N=1  -> alpha = beta                       (Euler / DeltaNet)
         N=2  -> alpha = beta (1 - x/2)             (RK-2, Eq. 11)
         N=4  -> alpha = beta (1 - x/2 + x^2/6 - x^3/24)   (RK-4, Eq. 12)
         N=inf-> alpha = (1 - e^{-x}) / lambda      (EFLA, Eq. 20)

So the ONLY difference between DeltaNet, RK-N and EFLA is this scalar gate;
one chunkwise kernel serves the whole family.  EFLA computes the numerator
with expm1 for precision at small x and clips lambda at EPS_LAMBDA to avoid
division by zero (paper Appendix A).
"""

import math

import jax.numpy as jnp

# Paper Appendix A: lower bound on ||k||^2 to prevent division by zero.
EPS_LAMBDA = 1e-12


def gate_series(x, order: int):
    """g_N(x) = sum_{m=1}^{N} (-x)^m / m!  — truncated Taylor series of e^{-x}-1.

    Evaluated with Horner's scheme for numerical stability; ``x`` is
    beta*lambda elementwise.  ``order`` is the integrator order N >= 1.
    """
    if order < 1:
        raise ValueError(f"integrator order must be >= 1, got {order}")
    # Horner: g = -x(1/1! - x(1/2! - x(1/3! - ...)))  i.e.
    # g = sum_{m=1}^N (-x)^m/m!  ==  acc_1 where acc_m = (-x)/m * (1 + acc_{m+1})
    acc = jnp.zeros_like(x)
    for m in range(order, 0, -1):
        acc = (-x) / m * (1.0 + acc)
    return acc


def alpha_rk(beta, lam, order: int):
    """Order-N Runge-Kutta gate  alpha_N = -g_N(beta*lambda) / lambda."""
    lam = jnp.maximum(lam, EPS_LAMBDA)
    x = beta * lam
    return -gate_series(x, order) / lam


def alpha_euler(beta, lam=None):
    """Order-1 (explicit Euler) gate: DeltaNet's alpha is just beta."""
    del lam
    return beta


def alpha_efla(beta, lam):
    """Exact (RK-inf) gate  alpha = (1 - e^{-beta*lambda}) / lambda  (Eq. 20).

    Uses ``-expm1(-x)`` so the numerator keeps full precision as
    beta*lambda -> 0, where alpha -> beta (the delta-rule limit, paper §6).
    """
    lam = jnp.maximum(lam, EPS_LAMBDA)
    return -jnp.expm1(-beta * lam) / lam


def alpha_named(beta, lam, kind: str, order: int = 4):
    """Dispatch helper used by the model layer: kind in {efla, euler, rk}."""
    if kind == "efla":
        return alpha_efla(beta, lam)
    if kind == "euler":
        return alpha_euler(beta, lam)
    if kind == "rk":
        return alpha_rk(beta, lam, order)
    raise ValueError(f"unknown gate kind {kind!r}")


def factorial_coeffs(order: int):
    """[1/1!, 1/2!, ..., 1/order!] — exposed for the rust-side mirrors' tests."""
    return [1.0 / math.factorial(m) for m in range(1, order + 1)]
