"""DeltaNet baseline (Schlag et al. 2021; Yang et al. 2024b), paper Eq. 5.

DeltaNet is the order-1 (explicit Euler) member of the integrator family:
alpha_t = beta_t, with L2-normalized keys (||k_t|| = 1) — normalization is
what keeps the Euler transition I - beta k k^T contractive for beta in (0, 2).
Reuses the exact same chunkwise kernel as EFLA; only the gate differs.
"""

import jax
import jax.numpy as jnp

from .chunkwise import DEFAULT_CHUNK, chunkwise_delta


def l2_normalize(x, axis=-1, eps=1e-6):
    """x * rsqrt(max(||x||^2, eps^2)) along ``axis``.

    Written via rsqrt-of-clamped-square so the gradient at x == 0 is exactly
    0 — the sqrt-then-clamp form has d(sqrt)/dx = inf at 0, and 0 * inf = NaN
    poisons training whenever a token's key is exactly zero (e.g. dark sMNIST
    rows through zero-initialized biases)."""
    xf = x.astype(jnp.float32)
    ss = jnp.sum(jnp.square(xf), axis=axis, keepdims=True)
    return (xf * jax.lax.rsqrt(jnp.maximum(ss, eps * eps))).astype(x.dtype)


def deltanet_attention(q, k, v, beta, s0=None, chunk: int = DEFAULT_CHUNK, normalize: bool = True):
    """DeltaNet attention over a full sequence.

    Args mirror ``efla_attention``; ``normalize=True`` applies the paper's
    L2 normalization to q and k (DeltaNet discards the key norm — exactly the
    degree of freedom EFLA keeps).
    """
    if normalize:
        q = l2_normalize(q)
        k = l2_normalize(k)
    alpha = beta.astype(jnp.float32)
    return chunkwise_delta(q, k, v, alpha, s0=s0, chunk=chunk)


def deltanet_recurrent_step(s, q, k, v, beta, normalize: bool = True):
    """Single-token DeltaNet decode step (Euler gate), for serving parity."""
    if normalize:
        q = l2_normalize(q)
        k = l2_normalize(k)
    kf = k.astype(jnp.float32)
    vf = v.astype(jnp.float32)
    alpha = beta.astype(jnp.float32)
    stk = jnp.einsum("bhkv,bhk->bhv", s, kf)
    s_new = s + alpha[..., None, None] * jnp.einsum("bhk,bhv->bhkv", kf, vf - stk)
    o = jnp.einsum("bhkv,bhk->bhv", s_new, q.astype(jnp.float32))
    return o.astype(q.dtype), s_new
