"""Public EFLA attention entry points (paper Eq. 20 + §4).

EFLA = the generalized delta-rule chunkwise kernel driven by the *exact* gate
alpha_t = (1 - e^{-beta_t ||k_t||^2}) / ||k_t||^2.  Keys are NOT normalized:
the key norm acts as the dynamic spectral gate (paper §6) and retaining it is
the extra degree of freedom the paper credits for EFLA's edge (§8).
"""

import jax.numpy as jnp

from .chunkwise import DEFAULT_CHUNK, chunkwise_delta
from .gates import EPS_LAMBDA, alpha_efla


def efla_attention(q, k, v, beta, s0=None, chunk: int = DEFAULT_CHUNK):
    """Error-Free Linear Attention over a full sequence.

    Args:
      q, k: (B, H, L, Dk) — unnormalized keys (the norm is the gate input).
      v:    (B, H, L, Dv)
      beta: (B, H, L) per-token step size (sigmoid- or softplus-activated
            upstream; this function is activation-agnostic).
      s0:   optional initial state (B, H, Dk, Dv).
      chunk: chunkwise parallel block size C.

    Returns (out, final_state).
    """
    lam = jnp.sum(jnp.square(k.astype(jnp.float32)), axis=-1)  # (B,H,L)
    alpha = alpha_efla(beta.astype(jnp.float32), lam)
    return chunkwise_delta(q, k, v, alpha, s0=s0, chunk=chunk)


def efla_recurrent_step(s, q, k, v, beta):
    """Single-token decode step, O(Dk*Dv) — the serving hot path's L2 graph.

        lambda = ||k||^2,  alpha = (1 - e^{-beta lambda}) / lambda
        S' = S + alpha k (v - S^T k)^T,   o = S'^T q

    Args:
      s: (B, H, Dk, Dv) float32 running state.
      q, k: (B, H, Dk);  v: (B, H, Dv);  beta: (B, H).

    Returns (o, s') with o: (B, H, Dv).
    """
    qf = q.astype(jnp.float32)
    kf = k.astype(jnp.float32)
    vf = v.astype(jnp.float32)
    bf = beta.astype(jnp.float32)
    lam = jnp.maximum(jnp.sum(kf * kf, axis=-1), EPS_LAMBDA)  # (B,H)
    alpha = -jnp.expm1(-bf * lam) / lam
    stk = jnp.einsum("bhkv,bhk->bhv", s, kf)
    s_new = s + alpha[..., None, None] * jnp.einsum("bhk,bhv->bhkv", kf, vf - stk)
    o = jnp.einsum("bhkv,bhk->bhv", s_new, qf)
    return o.astype(q.dtype), s_new
