"""Pure-jnp oracles for the generalized delta-rule recurrence.

These are the CORE correctness signal: every Pallas kernel and every chunkwise
formulation is pytest-checked against ``sequential_delta`` (a literal
token-by-token ``lax.scan`` of paper Eq. 20/21), and the Rust reference
implementation mirrors the same math and is cross-checked through golden
vectors emitted by ``aot.py``.

Shapes follow the (B, H, L, D) convention used throughout the repo:
  q, k : (B, H, L, Dk)     v : (B, H, L, Dv)     alpha : (B, H, L)
  out  : (B, H, L, Dv)     state : (B, H, Dk, Dv)
"""

import jax
import jax.numpy as jnp


def sequential_delta_with_state(q, k, v, alpha, s0=None):
    """Token-by-token generalized delta rule (paper Eq. 20).

        S_t = (I - alpha_t k_t k_t^T) S_{t-1} + alpha_t k_t v_t^T
            = S_{t-1} + alpha_t k_t (v_t - S_{t-1}^T k_t)^T
        o_t = S_t^T q_t

    Returns ``(out, final_state)``.  Computation is in float32 regardless of
    input dtype (state accumulation in low precision is exactly the error
    source the paper is about).
    """
    b, h, l, dk = q.shape
    dv = v.shape[-1]
    qf = q.astype(jnp.float32)
    kf = k.astype(jnp.float32)
    vf = v.astype(jnp.float32)
    af = alpha.astype(jnp.float32)
    if s0 is None:
        s0 = jnp.zeros((b, h, dk, dv), jnp.float32)
    else:
        s0 = s0.astype(jnp.float32)

    def step(s, inp):
        qt, kt, vt, at = inp  # (B,H,Dk), (B,H,Dk), (B,H,Dv), (B,H)
        # S^T k : (B,H,Dv)
        stk = jnp.einsum("bhkv,bhk->bhv", s, kt)
        s = s + at[..., None, None] * jnp.einsum("bhk,bhv->bhkv", kt, vt - stk)
        o = jnp.einsum("bhkv,bhk->bhv", s, qt)
        return s, o

    xs = (
        jnp.moveaxis(qf, 2, 0),
        jnp.moveaxis(kf, 2, 0),
        jnp.moveaxis(vf, 2, 0),
        jnp.moveaxis(af, 2, 0),
    )
    s_final, outs = jax.lax.scan(step, s0, xs)
    out = jnp.moveaxis(outs, 0, 2).astype(q.dtype)
    return out, s_final


def sequential_delta(q, k, v, alpha, s0=None):
    """Outputs only — see ``sequential_delta_with_state``."""
    out, _ = sequential_delta_with_state(q, k, v, alpha, s0)
    return out


def naive_quadratic_delta(q, k, v, alpha):
    """O(L^2) unrolled form of the same recurrence (paper Eq. 21).

    Materializes every per-token Householder-like factor explicitly:

        S_t = sum_i (prod_{j=i+1..t} (I - a_j k_j k_j^T)) a_i k_i v_i^T

    Deliberately brute force (python loop over L, product over matrices) —
    only usable for tiny shapes, exists purely as an independent oracle for
    the oracle.
    """
    b, h, l, dk = q.shape
    dv = v.shape[-1]
    qf = q.astype(jnp.float32)
    kf = k.astype(jnp.float32)
    vf = v.astype(jnp.float32)
    af = alpha.astype(jnp.float32)
    eye = jnp.eye(dk, dtype=jnp.float32)
    outs = []
    s = jnp.zeros((b, h, dk, dv), jnp.float32)
    for t in range(l):
        kt = kf[:, :, t]  # (B,H,Dk)
        vt = vf[:, :, t]
        at = af[:, :, t]
        house = eye - at[..., None, None] * jnp.einsum("bhi,bhj->bhij", kt, kt)
        s = jnp.einsum("bhij,bhjv->bhiv", house, s) + at[..., None, None] * jnp.einsum(
            "bhk,bhv->bhkv", kt, vt
        )
        outs.append(jnp.einsum("bhkv,bhk->bhv", s, qf[:, :, t]))
    return jnp.stack(outs, axis=2).astype(q.dtype)
