"""AOT lowering driver: every L2 graph -> artifacts/<name>.hlo.txt + manifest.

Interchange format is HLO *text* (NOT ``lowered.compile().serialize()``): the
image's xla_extension 0.5.1 rejects jax>=0.5 protos with 64-bit instruction
ids, while the text parser reassigns ids and round-trips cleanly (see
/opt/xla-example/README.md).  The Rust runtime loads each file with
``HloModuleProto::from_text_file`` and compiles it on the PJRT CPU client.

Emitted per artifact:
  * ``<name>.hlo.txt``    — the lowered module (entry returns ONE tuple).
  * a manifest entry      — input/output names, shapes, dtypes, in the flat
                            deterministic order both sides agree on.

Also emits ``golden.json``: small fixed-seed input/output vectors from the
L1 kernels, used by the Rust unit tests to pin the cross-language numerics.

Usage:  python -m compile.aot --out-dir ../artifacts [--set core|full|tiny]
"""

import argparse
import json
import os
import time
from collections import OrderedDict

import jax
import jax.numpy as jnp
import numpy as np
from jax._src.lib import xla_client as xc

from . import classifier as clf
from . import model as mdl
from . import train as trn
from .kernels import chunkwise_delta, alpha_efla
from .kernels.gates import alpha_rk

DTYPE_NAMES = {
    jnp.float32.dtype: "f32",
    jnp.int32.dtype: "s32",
    jnp.uint32.dtype: "u32",
}


def to_hlo_text(lowered) -> str:
    mlir_mod = lowered.compiler_ir("stablehlo")
    comp = xc._xla.mlir.mlir_module_to_xla_computation(
        str(mlir_mod), use_tuple_args=False, return_tuple=True
    )
    return comp.as_hlo_text()


def _iospec(avals, names):
    out = []
    for name, a in zip(names, avals):
        out.append(
            {
                "name": name,
                "shape": [int(s) for s in a.shape],
                "dtype": DTYPE_NAMES[jnp.dtype(a.dtype)],
            }
        )
    return out


class Emitter:
    def __init__(self, out_dir: str):
        self.out_dir = out_dir
        self.manifest = {"version": 1, "artifacts": OrderedDict()}
        os.makedirs(out_dir, exist_ok=True)

    def emit(self, name, fn, in_specs, in_names, out_names, meta):
        """Lower ``fn(*in_specs)`` and write ``<name>.hlo.txt`` + manifest."""
        t0 = time.time()
        # keep_unused: parameters not touched by a graph (e.g. `adecay` in
        # non-adaptive mixers) must STAY inputs, or the compiled program's
        # arity would diverge from the manifest the Rust runtime trusts.
        lowered = jax.jit(fn, keep_unused=True).lower(*in_specs)
        text = to_hlo_text(lowered)
        path = os.path.join(self.out_dir, f"{name}.hlo.txt")
        with open(path, "w") as f:
            f.write(text)
        out_avals = jax.eval_shape(fn, *in_specs)
        flat_out, _ = jax.tree_util.tree_flatten(out_avals)
        flat_in, _ = jax.tree_util.tree_flatten(in_specs)
        entry = {
            "file": f"{name}.hlo.txt",
            "inputs": _iospec(flat_in, in_names),
            "outputs": _iospec(flat_out, out_names),
        }
        entry.update(meta)
        self.manifest["artifacts"][name] = entry
        print(f"  [{time.time()-t0:6.1f}s] {name}: {len(text)/1e6:.2f} MB, "
              f"{len(flat_in)} in / {len(flat_out)} out")

    def save_manifest(self):
        path = os.path.join(self.out_dir, "manifest.json")
        with open(path, "w") as f:
            json.dump(self.manifest, f, indent=1)
        print(f"manifest: {path} ({len(self.manifest['artifacts'])} artifacts)")


def f32(shape):
    return jax.ShapeDtypeStruct(shape, jnp.float32)


def i32(shape):
    return jax.ShapeDtypeStruct(shape, jnp.int32)


def u32(shape):
    return jax.ShapeDtypeStruct(shape, jnp.uint32)


# --------------------------------------------------------------------------
# LM artifacts
# --------------------------------------------------------------------------


def emit_lm(em: Emitter, preset: str, mixer: str, batch: int, seq: int,
            graphs=("init", "step", "eval"), decode_batch: int = 4,
            prefill_len: int = 128):
    cfg = mdl.preset_with_mixer(preset, mixer)
    abstract = mdl.init_params(jax.random.PRNGKey(0), cfg, abstract=True)
    pnames = list(abstract.keys())
    pspecs = [abstract[k] for k in pnames]
    base = f"lm_{preset}_{mixer}"
    meta_common = {
        "task": "lm",
        "preset": preset,
        "mixer": mixer,
        "param_names": pnames,
        "config": {
            "vocab": cfg.vocab, "d_model": cfg.d_model, "n_layers": cfg.n_layers,
            "n_heads": cfg.n_heads, "head_dim": cfg.head_dim, "chunk": cfg.chunk,
            "mlp_mult": cfg.mlp_mult,
        },
        "batch": batch,
        "seq": seq,
    }

    def pack(d):
        return list(d.values())

    if "init" in graphs:
        def init_fn(seed):
            key = jax.random.PRNGKey(seed)
            return tuple(pack(mdl.init_params(key, cfg)))

        em.emit(f"{base}_init", init_fn, (u32(()),), ["seed"],
                pnames, dict(meta_common, graph="init"))

    if "step" in graphs:
        def step_fn(*args):
            n = len(pnames)
            params = OrderedDict(zip(pnames, args[:n]))
            m = OrderedDict(zip(pnames, args[n:2 * n]))
            v = OrderedDict(zip(pnames, args[2 * n:3 * n]))
            step, tokens, targets, lr = args[3 * n:]
            new_p, new_m, new_v, loss, gnorm = trn.train_step(
                cfg, params, m, v, step, tokens, targets, lr)
            return tuple(pack(new_p)) + tuple(pack(new_m)) + tuple(pack(new_v)) + (loss, gnorm)

        in_specs = tuple(pspecs) * 3 + (f32(()), i32((batch, seq)), i32((batch, seq)), f32(()))
        in_names = ([f"p.{k}" for k in pnames] + [f"m.{k}" for k in pnames]
                    + [f"v.{k}" for k in pnames] + ["step", "tokens", "targets", "lr"])
        out_names = ([f"p.{k}" for k in pnames] + [f"m.{k}" for k in pnames]
                     + [f"v.{k}" for k in pnames] + ["loss", "gnorm"])
        em.emit(f"{base}_step", step_fn, in_specs, in_names, out_names,
                dict(meta_common, graph="step"))

    if "eval" in graphs:
        def eval_fn(*args):
            params = OrderedDict(zip(pnames, args[:len(pnames)]))
            tokens, targets = args[len(pnames):]
            return trn.eval_step(cfg, params, tokens, targets)

        em.emit(f"{base}_eval", eval_fn,
                tuple(pspecs) + (i32((batch, seq)), i32((batch, seq))),
                [f"p.{k}" for k in pnames] + ["tokens", "targets"],
                ["loss_sum", "count", "correct"],
                dict(meta_common, graph="eval"))

    if "logits_last" in graphs:
        def logits_fn(*args):
            params = OrderedDict(zip(pnames, args[:len(pnames)]))
            (tokens,) = args[len(pnames):]
            return (trn.logits_last(cfg, params, tokens),)

        em.emit(f"{base}_logits_last", logits_fn,
                tuple(pspecs) + (i32((batch, seq)),),
                [f"p.{k}" for k in pnames] + ["tokens"],
                ["logits"],
                dict(meta_common, graph="logits_last"))

    if "decode" in graphs:
        st = mdl.zero_decode_state(cfg, decode_batch)
        snames = list(st.keys())
        sspecs = [jax.ShapeDtypeStruct(v.shape, v.dtype) for v in st.values()]

        def decode_fn(*args):
            params = OrderedDict(zip(pnames, args[:len(pnames)]))
            state = OrderedDict(zip(snames, args[len(pnames):-1]))
            token = args[-1]
            logits, new_state = mdl.decode_step(cfg, params, state, token)
            return (logits,) + tuple(new_state.values())

        em.emit(f"{base}_decode", decode_fn,
                tuple(pspecs) + tuple(sspecs) + (i32((decode_batch,)),),
                [f"p.{k}" for k in pnames] + [f"s.{k}" for k in snames] + ["token"],
                ["logits"] + [f"s.{k}" for k in snames],
                dict(meta_common, graph="decode", decode_batch=decode_batch,
                     state_names=snames))

    if "prefill" in graphs:
        st = mdl.zero_decode_state(cfg, decode_batch)
        snames = list(st.keys())

        def prefill_fn(*args):
            params = OrderedDict(zip(pnames, args[:len(pnames)]))
            (tokens,) = args[len(pnames):]
            logits, state = mdl.prefill(cfg, params, tokens)
            return (logits,) + tuple(state.values())

        em.emit(f"{base}_prefill", prefill_fn,
                tuple(pspecs) + (i32((decode_batch, prefill_len)),),
                [f"p.{k}" for k in pnames] + ["tokens"],
                ["logits"] + [f"s.{k}" for k in snames],
                dict(meta_common, graph="prefill", decode_batch=decode_batch,
                     prefill_len=prefill_len, state_names=snames))


# --------------------------------------------------------------------------
# Classifier artifacts (Fig. 1 / Fig. 2)
# --------------------------------------------------------------------------


def emit_classifier(em: Emitter, mixer: str, batch: int):
    cfg = clf.ClassifierConfig(mixer=mixer)
    abstract = clf.init_params(jax.random.PRNGKey(0), cfg, abstract=True)
    pnames = list(abstract.keys())
    pspecs = [abstract[k] for k in pnames]
    base = f"clf_{mixer}"
    meta_common = {
        "task": "classifier",
        "mixer": mixer,
        "param_names": pnames,
        "config": {
            "d_model": cfg.d_model, "n_layers": cfg.n_layers,
            "n_heads": cfg.n_heads, "head_dim": cfg.head_dim, "chunk": cfg.chunk,
        },
        "batch": batch,
        "seq": clf.SEQ_LEN,
    }

    def init_fn(seed):
        return tuple(clf.init_params(jax.random.PRNGKey(seed), cfg).values())

    em.emit(f"{base}_init", init_fn, (u32(()),), ["seed"], pnames,
            dict(meta_common, graph="init"))

    def step_fn(*args):
        n = len(pnames)
        params = OrderedDict(zip(pnames, args[:n]))
        m = OrderedDict(zip(pnames, args[n:2 * n]))
        v = OrderedDict(zip(pnames, args[2 * n:3 * n]))
        step, pixels, labels, lr = args[3 * n:]
        new_p, new_m, new_v, loss, gnorm = clf.train_step(
            cfg, params, m, v, step, pixels, labels, lr)
        return tuple(new_p.values()) + tuple(new_m.values()) + tuple(new_v.values()) + (loss, gnorm)

    em.emit(f"{base}_step", step_fn,
            tuple(pspecs) * 3 + (f32(()), f32((batch, clf.SEQ_LEN)), i32((batch,)), f32(())),
            [f"p.{k}" for k in pnames] + [f"m.{k}" for k in pnames]
            + [f"v.{k}" for k in pnames] + ["step", "pixels", "labels", "lr"],
            [f"p.{k}" for k in pnames] + [f"m.{k}" for k in pnames]
            + [f"v.{k}" for k in pnames] + ["loss", "gnorm"],
            dict(meta_common, graph="step"))

    def eval_fn(*args):
        params = OrderedDict(zip(pnames, args[:len(pnames)]))
        pixels, labels = args[len(pnames):]
        return clf.eval_step(cfg, params, pixels, labels)

    em.emit(f"{base}_eval", eval_fn,
            tuple(pspecs) + (f32((batch, clf.SEQ_LEN)), i32((batch,))),
            [f"p.{k}" for k in pnames] + ["pixels", "labels"],
            ["loss_sum", "correct"],
            dict(meta_common, graph="eval"))


# --------------------------------------------------------------------------
# Golden vectors for the Rust cross-checks
# --------------------------------------------------------------------------


def emit_golden(out_dir: str):
    key = jax.random.PRNGKey(12345)
    ks = jax.random.split(key, 4)
    b, h, l, d = 1, 2, 12, 4
    q = jax.random.normal(ks[0], (b, h, l, d), jnp.float32)
    k = jax.random.normal(ks[1], (b, h, l, d), jnp.float32) * 0.7
    v = jax.random.normal(ks[2], (b, h, l, d), jnp.float32)
    beta = jax.nn.sigmoid(jax.random.normal(ks[3], (b, h, l), jnp.float32))
    lam = jnp.sum(k * k, axis=-1)
    alpha = alpha_efla(beta, lam)
    out, s = chunkwise_delta(q, k, v, alpha, chunk=4)

    xs = np.linspace(0.0, 8.0, 33)
    gates = {
        f"rk{n}": np.asarray(alpha_rk(jnp.asarray(xs), jnp.ones_like(jnp.asarray(xs)), n)).tolist()
        for n in (1, 2, 3, 4, 6)
    }
    gates["efla"] = np.asarray(alpha_efla(jnp.asarray(xs), jnp.ones_like(jnp.asarray(xs)))).tolist()

    golden = {
        "chunkwise": {
            "shape": [b, h, l, d],
            "chunk": 4,
            "q": np.asarray(q).ravel().tolist(),
            "k": np.asarray(k).ravel().tolist(),
            "v": np.asarray(v).ravel().tolist(),
            "beta": np.asarray(beta).ravel().tolist(),
            "alpha": np.asarray(alpha).ravel().tolist(),
            "out": np.asarray(out).ravel().tolist(),
            "state": np.asarray(s).ravel().tolist(),
        },
        "gates": {"x": xs.tolist(), **gates},
    }
    path = os.path.join(out_dir, "golden.json")
    with open(path, "w") as f:
        json.dump(golden, f)
    print(f"golden vectors: {path}")


# --------------------------------------------------------------------------


def main():
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--out-dir", default="../artifacts")
    ap.add_argument("--set", dest="which", default="core",
                    choices=["tiny", "core", "full"])
    args = ap.parse_args()

    em = Emitter(args.out_dir)
    t0 = time.time()

    # tiny LM: integration tests + quickstart (all graphs incl. serving path)
    for mixer in ("efla", "deltanet"):
        emit_lm(em, "tiny", mixer, batch=4, seq=64,
                graphs=("init", "step", "eval", "logits_last", "decode", "prefill"),
                decode_batch=4, prefill_len=32)

    if args.which in ("core", "full"):
        # mini LM: Table-1 bench rows (all four variants)
        for mixer in ("efla", "deltanet", "efla_adaptive", "efla_loose"):
            emit_lm(em, "mini", mixer, batch=8, seq=128,
                    graphs=("init", "step", "eval", "logits_last"))
        # small LM: deeper example runs + serving artifacts
        for mixer in ("efla", "deltanet"):
            emit_lm(em, "small", mixer, batch=4, seq=256,
                    graphs=("init", "step", "eval"))
        emit_lm(em, "small", "efla", batch=4, seq=256,
                graphs=("decode", "prefill"), decode_batch=8, prefill_len=128)
        # classifier: Fig-1/Fig-2 (paper bs=128 scaled to the 1-core testbed)
        for mixer in ("efla", "deltanet"):
            emit_classifier(em, mixer, batch=16)
        # MAD: tiny vocab-64 models, seq 128 (Table 2)
        for mixer in ("efla", "deltanet"):
            emit_lm(em, "mad", mixer, batch=16, seq=128,
                    graphs=("init", "step", "eval"))

    if args.which == "full":
        # ~100M end-to-end model (examples/train_lm.rs --preset 100m)
        for mixer in ("efla",):
            emit_lm(em, "100m", mixer, batch=2, seq=512,
                    graphs=("init", "step", "eval"))

    emit_golden(em.out_dir)
    em.save_manifest()
    print(f"total {time.time()-t0:.1f}s")


# "mad" preset registered here to keep model.PRESETS purely architectural
mdl.PRESETS.setdefault(
    "mad",
    mdl.ModelConfig(vocab=64, d_model=128, n_layers=2, n_heads=2, head_dim=64, chunk=32),
)

if __name__ == "__main__":
    main()
