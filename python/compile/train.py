"""Build-time training graphs: AdamW + grad-clip fwd/bwd as one jitted step.

The entire optimizer lives inside the HLO artifact: the Rust coordinator only
threads (params, m, v) buffers through the step executable and supplies the
scalar learning rate (L3 owns the schedule).  All state is float32.
"""

from collections import OrderedDict

import jax
import jax.numpy as jnp

from .model import ModelConfig, cross_entropy, forward, loss_fn

ADAM_B1 = 0.9
ADAM_B2 = 0.95
ADAM_EPS = 1e-8
WEIGHT_DECAY = 0.1  # paper Appendix A
GRAD_CLIP = 1.0  # paper Appendix A


def _decay_mask(name: str, p) -> bool:
    """Weight decay on matrices only; norms/biases/scalars exempt."""
    return p.ndim >= 2


def zero_opt_state(params):
    m = OrderedDict((k, jnp.zeros_like(v)) for k, v in params.items())
    v = OrderedDict((k, jnp.zeros_like(vv)) for k, vv in params.items())
    return m, v


def global_norm(grads):
    return jnp.sqrt(sum(jnp.sum(jnp.square(g)) for g in grads.values()))


def adamw_update(params, grads, m, v, step, lr):
    """AdamW with bias correction + decoupled weight decay + global-norm clip.

    ``step`` is the 1-based float32 step counter (provided by L3).
    Returns (params', m', v', gnorm)."""
    gnorm = global_norm(grads)
    scale = jnp.minimum(1.0, GRAD_CLIP / jnp.maximum(gnorm, 1e-12))
    bc1 = 1.0 - ADAM_B1**step
    bc2 = 1.0 - ADAM_B2**step
    new_p, new_m, new_v = OrderedDict(), OrderedDict(), OrderedDict()
    for k in params:
        g = grads[k] * scale
        mk = ADAM_B1 * m[k] + (1.0 - ADAM_B1) * g
        vk = ADAM_B2 * v[k] + (1.0 - ADAM_B2) * jnp.square(g)
        update = (mk / bc1) / (jnp.sqrt(vk / bc2) + ADAM_EPS)
        if _decay_mask(k, params[k]):
            update = update + WEIGHT_DECAY * params[k]
        new_p[k] = params[k] - lr * update
        new_m[k] = mk
        new_v[k] = vk
    return new_p, new_m, new_v, gnorm


def train_step(cfg: ModelConfig, params, m, v, step, tokens, targets, lr):
    """One fused fwd+bwd+AdamW step.

    tokens/targets: (B, L) int32, targets use -1 for ignored positions.
    Returns (params', m', v', loss, gnorm)."""
    loss, grads = jax.value_and_grad(lambda p: loss_fn(cfg, p, tokens, targets))(params)
    new_p, new_m, new_v, gnorm = adamw_update(params, grads, m, v, step, lr)
    return new_p, new_m, new_v, loss, gnorm


def eval_step(cfg: ModelConfig, params, tokens, targets):
    """Eval statistics for perplexity/accuracy aggregation on the Rust side.

    Returns (loss_sum, token_count, correct_count)."""
    logits = forward(cfg, params, tokens)
    _, loss_sum, count, correct = cross_entropy(logits, targets)
    return loss_sum, count, correct


def logits_last(cfg: ModelConfig, params, tokens):
    """Logits at the final position only — used by downstream probes."""
    logits = forward(cfg, params, tokens)
    return logits[:, -1]


def cosine_lr(step: float, peak: float, warmup: float, total: float, floor: float) -> float:
    """Host-side schedule mirror (the authoritative copy lives in Rust;
    this one exists so python tests can cross-check the Rust mirror)."""
    import math

    if step < warmup:
        return peak * step / max(warmup, 1.0)
    t = min(1.0, (step - warmup) / max(total - warmup, 1.0))
    return floor + 0.5 * (peak - floor) * (1.0 + math.cos(math.pi * t))
