"""Layer-2 JAX model: transformer LM with EFLA/DeltaNet token mixers.

Architecture follows Yang et al. 2024b (the paper adopts it verbatim, §5.2):
each block is {RMSNorm -> token mixer -> residual; RMSNorm -> SwiGLU MLP ->
residual}; the token mixer projects q/k/v, applies a short depthwise causal
conv (kernel size 4, paper Appendix A) + SiLU to each, computes a per-head
step size beta, and runs the chunkwise delta-rule kernel with the
variant-specific gate:

  deltanet       : L2-normalized q/k, alpha = beta = sigmoid(w_b x)
  efla           : unnormalized keys, alpha = (1 - e^{-beta lam}) / lam
  efla_adaptive  : beta~ = softplus(a) * beta (learnable per-head scalar a,
                   "EFLA + Adaptive Decay", §5.2)
  efla_loose     : beta = softplus(w_b x)  ("EFLA + Loose beta", §5.2)

Params live in a FLAT OrderedDict[str, jnp.ndarray] so the AOT manifest and
the Rust runtime agree on ordering without a pytree protocol.

Everything here is build-time Python: `aot.py` lowers init / train-step /
eval / prefill / decode graphs to HLO text once, and the Rust coordinator is
the only thing that ever executes them.
"""

import dataclasses
import math
from collections import OrderedDict
from typing import Optional

import jax
import jax.numpy as jnp

from .kernels import chunkwise_delta, l2_normalize
from .kernels.gates import EPS_LAMBDA, alpha_efla

CONV_K = 4  # short-conv kernel size (paper Appendix A)


@dataclasses.dataclass(frozen=True)
class ModelConfig:
    """Static architecture hyperparameters (baked into each artifact)."""

    vocab: int = 256
    d_model: int = 64
    n_layers: int = 2
    n_heads: int = 2
    head_dim: int = 32  # Dk = Dv per head
    mlp_mult: int = 4  # SwiGLU hidden = mlp_mult * d_model
    chunk: int = 64
    mixer: str = "efla"  # efla | deltanet | efla_adaptive | efla_loose
    norm_eps: float = 1e-6

    @property
    def inner(self) -> int:
        return self.n_heads * self.head_dim

    def param_count(self) -> int:
        shapes = init_params(jax.random.PRNGKey(0), self, abstract=True)
        return sum(int(math.prod(s.shape)) for s in shapes.values())


PRESETS = {
    "tiny": ModelConfig(vocab=256, d_model=64, n_layers=2, n_heads=2, head_dim=32, chunk=32),
    # "mini" is the Table-1 bench workhorse: big enough that the token-mixer
    # contrast shows, small enough that 4 variants train in minutes on the
    # single-core CPU testbed (DESIGN.md §5 scale substitution).
    "mini": ModelConfig(vocab=1024, d_model=192, n_layers=4, n_heads=3, head_dim=64, chunk=32),
    "small": ModelConfig(vocab=2048, d_model=320, n_layers=6, n_heads=5, head_dim=64),
    "medium": ModelConfig(vocab=4096, d_model=512, n_layers=8, n_heads=8, head_dim=64),
    "100m": ModelConfig(vocab=8192, d_model=768, n_layers=10, n_heads=6, head_dim=128),
}


def preset_with_mixer(name: str, mixer: str) -> ModelConfig:
    return dataclasses.replace(PRESETS[name], mixer=mixer)


# --------------------------------------------------------------------------
# Parameter initialization
# --------------------------------------------------------------------------


def _param_specs(cfg: ModelConfig):
    """Yield (name, shape, init_kind). init_kind: normal | zeros | ones."""
    d, inner, h = cfg.d_model, cfg.inner, cfg.n_heads
    yield "embed", (cfg.vocab, d), "normal"
    for i in range(cfg.n_layers):
        p = f"layer{i}."
        yield p + "norm_attn", (d,), "ones"
        yield p + "wq", (d, inner), "normal"
        yield p + "wk", (d, inner), "normal"
        yield p + "wv", (d, inner), "normal"
        yield p + "conv_q", (CONV_K, inner), "conv"
        yield p + "conv_k", (CONV_K, inner), "conv"
        yield p + "conv_v", (CONV_K, inner), "conv"
        yield p + "w_beta", (d, h), "normal"
        yield p + "adecay", (h,), "zeros"  # softplus(0)=log 2; only used by efla_adaptive
        yield p + "norm_out", (cfg.head_dim,), "ones"
        yield p + "wo", (inner, d), "normal"
        yield p + "norm_mlp", (d,), "ones"
        yield p + "w_gate", (d, cfg.mlp_mult * d), "normal"
        yield p + "w_up", (d, cfg.mlp_mult * d), "normal"
        yield p + "w_down", (cfg.mlp_mult * d, d), "normal"
    yield "norm_f", (d,), "ones"


def init_params(key, cfg: ModelConfig, abstract: bool = False) -> "OrderedDict[str, jnp.ndarray]":
    """Flat, deterministically-ordered parameter dict.

    With ``abstract=True`` returns ShapeDtypeStructs (no RNG) — used for
    manifests and param counting.
    """
    params = OrderedDict()
    specs = list(_param_specs(cfg))
    keys = jax.random.split(key, len(specs))
    for (name, shape, kind), k in zip(specs, keys):
        if abstract:
            params[name] = jax.ShapeDtypeStruct(shape, jnp.float32)
            continue
        if kind == "normal":
            fan_in = shape[0]
            params[name] = jax.random.normal(k, shape, jnp.float32) * (fan_in**-0.5)
        elif kind == "conv":
            # near-identity causal conv: last tap ~ 1, others small
            w = jax.random.normal(k, shape, jnp.float32) * 0.02
            params[name] = w.at[-1].add(1.0)
        elif kind == "ones":
            params[name] = jnp.ones(shape, jnp.float32)
        else:
            params[name] = jnp.zeros(shape, jnp.float32)
    return params


# --------------------------------------------------------------------------
# Building blocks
# --------------------------------------------------------------------------


def rms_norm(x, gain, eps):
    ms = jnp.mean(jnp.square(x), axis=-1, keepdims=True)
    return x * jax.lax.rsqrt(ms + eps) * gain


def causal_conv(x, w):
    """Depthwise causal conv along the sequence axis.

    x: (B, L, C);  w: (K, C).  out[t] = sum_j w[j] * x[t - (K-1) + j].
    """
    k = w.shape[0]
    xp = jnp.pad(x, ((0, 0), (k - 1, 0), (0, 0)))
    out = jnp.zeros_like(x)
    for j in range(k):
        out = out + xp[:, j : j + x.shape[1]] * w[j]
    return out


def conv_step(cache, x_t, w):
    """Single-token causal conv. cache: (B, K-1, C) previous inputs.

    Returns (out_t, new_cache)."""
    k = w.shape[0]
    window = jnp.concatenate([cache, x_t[:, None]], axis=1)  # (B, K, C)
    out = jnp.einsum("bkc,kc->bc", window, w)
    return out, window[:, 1:]


def _split_heads(x, h, dh):
    b, l, _ = x.shape
    return x.reshape(b, l, h, dh).transpose(0, 2, 1, 3)  # (B,H,L,Dh)


def _merge_heads(x):
    b, h, l, dh = x.shape
    return x.transpose(0, 2, 1, 3).reshape(b, l, h * dh)


def _gate_alpha(cfg: ModelConfig, params, prefix, x, k_heads):
    """Per-token scalar gate alpha (B,H,L) + the possibly-normalized q/k flag."""
    b_logits = jnp.einsum("bld,dh->blh", x, params[prefix + "w_beta"])  # (B,L,H)
    if cfg.mixer == "efla_loose":
        beta = jax.nn.softplus(b_logits)
    else:
        beta = jax.nn.sigmoid(b_logits)
    if cfg.mixer == "efla_adaptive":
        beta = beta * jax.nn.softplus(params[prefix + "adecay"])[None, None, :]
    beta = beta.transpose(0, 2, 1)  # (B,H,L)
    if cfg.mixer == "deltanet":
        return beta  # alpha = beta (Euler gate); keys normalized by caller
    lam = jnp.sum(jnp.square(k_heads), axis=-1)  # (B,H,L)
    return alpha_efla(beta, lam)


def mixer_forward(cfg: ModelConfig, params, prefix, x, s0=None):
    """Token mixer over a full sequence. x: (B, L, D). Returns (out, s_final)."""
    q = causal_conv(jnp.einsum("bld,de->ble", x, params[prefix + "wq"]), params[prefix + "conv_q"])
    k = causal_conv(jnp.einsum("bld,de->ble", x, params[prefix + "wk"]), params[prefix + "conv_k"])
    v = causal_conv(jnp.einsum("bld,de->ble", x, params[prefix + "wv"]), params[prefix + "conv_v"])
    q, k, v = jax.nn.silu(q), jax.nn.silu(k), jax.nn.silu(v)

    q = _split_heads(q, cfg.n_heads, cfg.head_dim)
    k = _split_heads(k, cfg.n_heads, cfg.head_dim)
    v = _split_heads(v, cfg.n_heads, cfg.head_dim)

    if cfg.mixer == "deltanet":
        q, k = l2_normalize(q), l2_normalize(k)
    alpha = _gate_alpha(cfg, params, prefix, x, k)

    o, s_final = chunkwise_delta(q, k, v, alpha, s0=s0, chunk=cfg.chunk)
    o = rms_norm(o, params[prefix + "norm_out"], cfg.norm_eps)  # per-head norm
    return jnp.einsum("ble,ed->bld", _merge_heads(o), params[prefix + "wo"]), s_final


def mlp_forward(cfg: ModelConfig, params, prefix, x):
    g = jax.nn.silu(jnp.einsum("bld,df->blf", x, params[prefix + "w_gate"]))
    u = jnp.einsum("bld,df->blf", x, params[prefix + "w_up"])
    return jnp.einsum("blf,fd->bld", g * u, params[prefix + "w_down"])


def forward(cfg: ModelConfig, params, tokens, s0_list=None, return_states: bool = False):
    """Full LM forward. tokens: (B, L) int32 -> logits (B, L, vocab)."""
    x = params["embed"][tokens]  # (B, L, D)
    states = []
    for i in range(cfg.n_layers):
        p = f"layer{i}."
        h = rms_norm(x, params[p + "norm_attn"], cfg.norm_eps)
        s0 = None if s0_list is None else s0_list[i]
        mixed, s_f = mixer_forward(cfg, params, p, h, s0=s0)
        x = x + mixed
        h = rms_norm(x, params[p + "norm_mlp"], cfg.norm_eps)
        x = x + mlp_forward(cfg, params, p, h)
        states.append(s_f)
    x = rms_norm(x, params["norm_f"], cfg.norm_eps)
    logits = jnp.einsum("bld,vd->blv", x, params["embed"])  # tied head
    if return_states:
        return logits, states
    return logits


def cross_entropy(logits, targets):
    """Masked CE. targets: (B, L) int32, -1 = ignore.

    Returns (loss_mean, loss_sum, count, correct)."""
    mask = (targets >= 0).astype(jnp.float32)
    tgt = jnp.maximum(targets, 0)
    logp = jax.nn.log_softmax(logits.astype(jnp.float32), axis=-1)
    nll = -jnp.take_along_axis(logp, tgt[..., None], axis=-1)[..., 0] * mask
    correct = (jnp.argmax(logits, axis=-1) == tgt).astype(jnp.float32) * mask
    count = jnp.maximum(mask.sum(), 1.0)
    return nll.sum() / count, nll.sum(), mask.sum(), correct.sum()


def loss_fn(cfg: ModelConfig, params, tokens, targets):
    logits = forward(cfg, params, tokens)
    loss, _, _, _ = cross_entropy(logits, targets)
    return loss


# --------------------------------------------------------------------------
# Recurrent (serving) path: O(1) per-token state
# --------------------------------------------------------------------------


def zero_decode_state(cfg: ModelConfig, batch: int):
    """Flat OrderedDict of per-layer recurrent state (served by Rust).

    Per layer: conv caches for q/k/v projections ((B, K-1, inner) each) and
    the attention state S ((B, H, Dk, Dv))."""
    st = OrderedDict()
    for i in range(cfg.n_layers):
        p = f"layer{i}."
        for nm in ("cache_q", "cache_k", "cache_v"):
            st[p + nm] = jnp.zeros((batch, CONV_K - 1, cfg.inner), jnp.float32)
        st[p + "s"] = jnp.zeros((batch, cfg.n_heads, cfg.head_dim, cfg.head_dim), jnp.float32)
    return st


def decode_step(cfg: ModelConfig, params, state, token):
    """One-token decode: token (B,) int32 -> (logits (B, vocab), new_state).

    This is the constant-memory inference path linear attention buys: no KV
    cache, just (conv caches + S) per layer."""
    x = params["embed"][token]  # (B, D)
    new_state = OrderedDict()
    for i in range(cfg.n_layers):
        p = f"layer{i}."
        h = rms_norm(x, params[p + "norm_attn"], cfg.norm_eps)
        q_t = h @ params[p + "wq"]
        k_t = h @ params[p + "wk"]
        v_t = h @ params[p + "wv"]
        q_t, cq = conv_step(state[p + "cache_q"], q_t, params[p + "conv_q"])
        k_t, ck = conv_step(state[p + "cache_k"], k_t, params[p + "conv_k"])
        v_t, cv = conv_step(state[p + "cache_v"], v_t, params[p + "conv_v"])
        q_t, k_t, v_t = jax.nn.silu(q_t), jax.nn.silu(k_t), jax.nn.silu(v_t)

        b, inner = q_t.shape
        hh, dh = cfg.n_heads, cfg.head_dim
        qh = q_t.reshape(b, hh, dh)
        kh = k_t.reshape(b, hh, dh)
        vh = v_t.reshape(b, hh, dh)

        b_logits = h @ params[p + "w_beta"]  # (B, H)
        if cfg.mixer == "efla_loose":
            beta = jax.nn.softplus(b_logits)
        else:
            beta = jax.nn.sigmoid(b_logits)
        if cfg.mixer == "efla_adaptive":
            beta = beta * jax.nn.softplus(params[p + "adecay"])[None, :]

        if cfg.mixer == "deltanet":
            qh, kh = l2_normalize(qh), l2_normalize(kh)
            alpha = beta
        else:
            lam = jnp.maximum(jnp.sum(kh * kh, axis=-1), EPS_LAMBDA)
            alpha = -jnp.expm1(-beta * lam) / lam

        s = state[p + "s"]
        stk = jnp.einsum("bhkv,bhk->bhv", s, kh)
        s_new = s + alpha[..., None, None] * jnp.einsum("bhk,bhv->bhkv", kh, vh - stk)
        o = jnp.einsum("bhkv,bhk->bhv", s_new, qh)  # (B, H, Dv)
        o = rms_norm(o, params[p + "norm_out"], cfg.norm_eps)
        x = x + o.reshape(b, inner) @ params[p + "wo"]

        hm = rms_norm(x, params[p + "norm_mlp"], cfg.norm_eps)
        g = jax.nn.silu(hm @ params[p + "w_gate"])
        u = hm @ params[p + "w_up"]
        x = x + (g * u) @ params[p + "w_down"]

        new_state[p + "cache_q"] = cq
        new_state[p + "cache_k"] = ck
        new_state[p + "cache_v"] = cv
        new_state[p + "s"] = s_new
    x = rms_norm(x, params["norm_f"], cfg.norm_eps)
    logits = x @ params["embed"].T
    return logits, new_state


def prefill(cfg: ModelConfig, params, tokens):
    """Chunkwise prefill: returns (last-token logits, decode state).

    Conv caches are rebuilt from the last K-1 *projected pre-conv* inputs, so
    prefill -> decode_step continuation is exact."""
    b, l = tokens.shape
    x = params["embed"][tokens]
    state = OrderedDict()
    for i in range(cfg.n_layers):
        p = f"layer{i}."
        h = rms_norm(x, params[p + "norm_attn"], cfg.norm_eps)
        for nm, w in (("cache_q", "wq"), ("cache_k", "wk"), ("cache_v", "wv")):
            proj = jnp.einsum("bld,de->ble", h, params[p + w])
            pad = jnp.pad(proj, ((0, 0), (CONV_K - 1, 0), (0, 0)))
            state[p + nm] = pad[:, l : l + CONV_K - 1]  # last K-1 pre-conv inputs
        mixed, s_f = mixer_forward(cfg, params, p, h)
        x = x + mixed
        hm = rms_norm(x, params[p + "norm_mlp"], cfg.norm_eps)
        x = x + mlp_forward(cfg, params, p, hm)
        state[p + "s"] = s_f
    x = rms_norm(x[:, -1], params["norm_f"], cfg.norm_eps)
    logits = x @ params["embed"].T
    return logits, state
