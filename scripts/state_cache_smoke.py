#!/usr/bin/env python3
"""Multi-turn session-state-cache smoke test of `efla serve` for CI.

Launches the release binary with the recurrent-state session cache
enabled, drives 3-turn conversations over the wire with the Python
stdlib only, and pins the PR's contract:

1.  ``GET /stats`` exposes the ``state_cache`` counter object;
2.  a 3-turn conversation carrying ``session_id`` returns tokens
    **bit-identical** to replaying each turn's full transcript through a
    cold prefill (no ``session_id``) on the same server;
3.  the ``state_cache`` counters are exact for that conversation:
    1 miss (turn 1 finds an empty cache), 2 hits (turns 2 and 3 restore
    the parked state), 0 evictions, 0 spills, 1 resident entry;
4.  a request without ``session_id`` leaves every counter untouched;
5.  a second server with ``--state-cache-bytes 1`` (no spill dir) evicts
    every snapshot immediately — both turns fall back to a cold prefill,
    still bit-identical, with hits 0 / misses 2 / evictions 2;
6.  both servers exit 0 on SIGTERM.

Counters are read with a short poll: the engine publishes stats after
the loop iteration that completes a request, so the ``/stats`` snapshot
can trail the response by one tick.

The servers' stderr goes to the log file given by ``--log`` (uploaded
as a CI artifact on failure). Exit code 0 = all checks pass.

Reproduce locally:
    cargo build --release
    python3 scripts/state_cache_smoke.py --bin target/release/efla
"""

import argparse
import http.client
import json
import shutil
import signal
import subprocess
import sys
import tempfile
import threading
import time

CHECKS = []


def check(name, ok, detail=""):
    CHECKS.append((name, ok))
    mark = "ok" if ok else "FAIL"
    print(f"smoke {mark}: {name}" + (f" — {detail}" if detail else ""))
    if not ok:
        raise AssertionError(f"{name}: {detail}")


CLIENT_TIMEOUT = 120.0


def post_generate(addr, body, timeout=None):
    host, port = addr.rsplit(":", 1)
    timeout = CLIENT_TIMEOUT if timeout is None else timeout
    conn = http.client.HTTPConnection(host, int(port), timeout=timeout)
    try:
        conn.request("POST", "/v1/generate", body=json.dumps(body),
                     headers={"Content-Type": "application/json"})
        resp = conn.getresponse()
        return resp.status, resp.read().decode("utf-8", "replace")
    finally:
        conn.close()


def get(addr, path, timeout=30):
    host, port = addr.rsplit(":", 1)
    conn = http.client.HTTPConnection(host, int(port), timeout=timeout)
    try:
        conn.request("GET", path)
        resp = conn.getresponse()
        return resp.status, resp.read().decode("utf-8", "replace")
    finally:
        conn.close()


def wait_for_ready(proc, deadline_secs):
    """Read stdout (from a helper thread, so the wait really times out)
    until the readiness line appears."""
    found = {}

    def reader():
        for line in proc.stdout:
            line = line.strip()
            print(f"server stdout: {line}")
            if line.startswith("SERVE listening on "):
                found["addr"] = line[len("SERVE listening on "):]
                return

    t = threading.Thread(target=reader, daemon=True)
    t.start()
    t.join(deadline_secs)
    if "addr" not in found:
        if proc.poll() is not None:
            raise AssertionError(
                f"server exited early with code {proc.returncode}")
        raise AssertionError(f"no readiness line within {deadline_secs}s")
    return found["addr"]


def generate_tokens(addr, tokens, max_tokens, session_id=None):
    """One greedy generate on a token-array prompt; returns the tokens."""
    body = {"tokens": tokens, "max_tokens": max_tokens, "temperature": 0.0}
    if session_id is not None:
        body["session_id"] = session_id
    for _ in range(120):
        status, text = post_generate(addr, body)
        if status != 429:
            break
        time.sleep(0.25)
    if status != 200:
        raise AssertionError(f"generate failed: {status} {text[:200]}")
    return json.loads(text.splitlines()[-1])["tokens"]


def state_cache_stats(addr):
    status, body = get(addr, "/stats")
    if status != 200:
        raise AssertionError(f"/stats failed: {status} {body[:200]}")
    return json.loads(body).get("state_cache")


def poll_state_cache(addr, pred, deadline_secs=10.0):
    """The engine publishes stats once per loop tick, so counters can
    trail the response briefly; poll until `pred` holds or time is up."""
    last = None
    end = time.time() + deadline_secs
    while time.time() < end:
        last = state_cache_stats(addr)
        if last is not None and pred(last):
            return last
        time.sleep(0.2)
    return last


def launch(args, log, extra_flags):
    cmd = [
        args.bin, "serve",
        "--listen", "127.0.0.1:0",
        "--steps", str(args.train_steps),
        "--corpus-bytes", "200000",
        "--queue-depth", "4",
        "--drain-timeout", "30",
    ] + extra_flags
    print(f"launching: {' '.join(cmd)}")
    return subprocess.Popen(cmd, stdout=subprocess.PIPE, stderr=log,
                            text=True)


def shutdown(proc, name):
    proc.send_signal(signal.SIGTERM)
    code = proc.wait(timeout=60)
    check(f"{name} clean exit after SIGTERM", code == 0, f"exit code {code}")


def run_cached_server(proc, args):
    addr = wait_for_ready(proc, args.startup_timeout)
    print(f"cached server ready on {addr}")

    # 1. /stats exposes the state_cache counter object.
    sc = state_cache_stats(addr)
    keys = ("hits", "misses", "evictions", "spills", "disk_hits",
            "entries", "bytes")
    check("stats has state_cache counters",
          sc is not None and all(k in sc for k in keys), f"{sc}")

    # 2. 3-turn conversation: each session turn must be bit-identical to
    # a cold full-transcript replay on the same server. The cold replay
    # carries no session_id, so it never touches the cache.
    base = [7, 3, 11, 2, 29, 5, 13, 17, 23, 1, 9, 31, 4, 19, 6, 27,
            8, 15, 10, 25, 12, 21, 14, 3]
    extras = [[41, 2, 37], [5, 43, 8, 3], [47, 1]]
    transcript = list(base)
    for turn in range(3):
        cold = generate_tokens(addr, transcript, 8)
        cached = generate_tokens(addr, transcript, 8, session_id="smoke")
        check(f"turn {turn + 1} bit-identical to full replay",
              cached == cold, f"{cached} vs {cold}")
        transcript = transcript + cached + extras[turn]

    # 3. exact counters for the conversation: turn 1 misses the empty
    # cache, turns 2 and 3 restore the parked state; a 64 MiB bound on a
    # few-KB state never evicts or spills.
    sc = poll_state_cache(
        addr, lambda s: s["hits"] == 2 and s["entries"] == 1)
    check("conversation counters exact",
          sc is not None and (sc["hits"], sc["misses"], sc["evictions"],
                              sc["spills"]) == (2, 1, 0, 0), f"{sc}")
    check("one resident session entry",
          sc["entries"] == 1 and sc["bytes"] > 0, f"{sc}")

    # 4. a sessionless request leaves every counter untouched.
    before = sc
    generate_tokens(addr, base, 4)
    time.sleep(1.0)
    after = state_cache_stats(addr)
    check("sessionless request leaves counters untouched",
          after == before, f"{before} -> {after}")

    shutdown(proc, "cached server")


def run_evicting_server(proc, args):
    addr = wait_for_ready(proc, args.startup_timeout)
    print(f"evicting server ready on {addr}")

    # 5. a 1-byte bound with no spill dir drops every snapshot: both
    # turns run cold and must still match the sessionless replay.
    base = [9, 4, 33, 6, 18, 2, 27, 5, 14, 7, 22, 3, 11, 8, 30, 1]
    t1_cold = generate_tokens(addr, base, 6)
    t1 = generate_tokens(addr, base, 6, session_id="evict")
    check("evicted turn 1 matches replay", t1 == t1_cold, f"{t1}")
    t2_prompt = base + t1 + [13, 2]
    t2_cold = generate_tokens(addr, t2_prompt, 6)
    t2 = generate_tokens(addr, t2_prompt, 6, session_id="evict")
    check("evicted turn 2 matches replay", t2 == t2_cold, f"{t2}")
    sc = poll_state_cache(addr, lambda s: s["evictions"] == 2)
    check("eviction counters exact",
          sc is not None and (sc["hits"], sc["misses"], sc["evictions"],
                              sc["entries"]) == (0, 2, 2, 0), f"{sc}")

    shutdown(proc, "evicting server")


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--bin", default="target/release/efla")
    ap.add_argument("--log", default="state_cache_smoke.log")
    ap.add_argument("--train-steps", type=int, default=5)
    ap.add_argument("--startup-timeout", type=float, default=300.0)
    ap.add_argument("--client-timeout", type=float, default=120.0,
                    help="socket timeout of every generate call, seconds")
    args = ap.parse_args()
    global CLIENT_TIMEOUT
    CLIENT_TIMEOUT = args.client_timeout

    spill_dir = tempfile.mkdtemp(prefix="efla_state_cache_smoke_")
    log = open(args.log, "w")
    proc = None
    try:
        proc = launch(args, log, [
            "--state-cache-bytes", str(64 << 20),
            "--state-cache-dir", spill_dir,
        ])
        run_cached_server(proc, args)

        log.write("\n--- evicting server (--state-cache-bytes 1) ---\n")
        log.flush()
        proc = launch(args, log, ["--state-cache-bytes", "1"])
        run_evicting_server(proc, args)
    except BaseException:
        if proc is not None and proc.poll() is None:
            proc.kill()
            proc.wait()
        log.close()
        print(f"--- server log ({args.log}) ---")
        sys.stdout.write(open(args.log).read())
        raise
    finally:
        shutil.rmtree(spill_dir, ignore_errors=True)
    log.close()
    print(f"all {len(CHECKS)} smoke checks passed")


if __name__ == "__main__":
    main()
