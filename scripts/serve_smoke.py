#!/usr/bin/env python3
"""End-to-end smoke test of the `efla serve` HTTP front end for CI.

Launches the release binary with ``--listen 127.0.0.1:0`` on a tiny
briefly-trained model, reads the ``SERVE listening on <addr>`` readiness
line from stdout, then drives the whole serving surface with the Python
stdlib only:

1.  ``GET /healthz`` and ``GET /stats`` are well-formed JSON (the
    latter versioned with ``"schema_version": 2``), and non-2xx
    answers carry the unified v1 error envelope
    ``{"error": {"code", "message"[, "retry_after_ms"]}}``;
2.  concurrent non-streamed ``POST /v1/generate`` requests all succeed
    with the requested token counts;
3.  a streamed request delivers one JSON line per token plus a final
    ``"done": true`` line whose token list matches the streamed pieces;
4.  greedy determinism: the same prompt twice returns identical tokens;
5.  queue overflow: a burst beyond slots + ``--queue-depth`` answers 429
    while the rest complete, and the service recovers afterwards;
6.  per-request deadline: a ``timeout_ms`` body field bounds the
    generation — the engine hands the slot back with finish_reason
    ``timeout`` and partial tokens instead of running out the budget;
7.  SIGTERM: in-flight requests drain to completion and the process
    exits 0 within the drain window.

Every client call carries an explicit socket timeout (``--client-timeout``
for generates), so a hung server fails the smoke instead of hanging CI.

The server's stderr goes to the log file given by ``--log`` (uploaded as
a CI artifact on failure). Exit code 0 = all checks pass.

Reproduce locally:
    cargo build --release
    python3 scripts/serve_smoke.py --bin target/release/efla
"""

import argparse
import http.client
import json
import signal
import subprocess
import sys
import threading
import time

CHECKS = []


def check(name, ok, detail=""):
    CHECKS.append((name, ok))
    mark = "ok" if ok else "FAIL"
    print(f"smoke {mark}: {name}" + (f" — {detail}" if detail else ""))
    if not ok:
        raise AssertionError(f"{name}: {detail}")


CLIENT_TIMEOUT = 120.0


def post_generate(addr, body, timeout=None):
    host, port = addr.rsplit(":", 1)
    timeout = CLIENT_TIMEOUT if timeout is None else timeout
    conn = http.client.HTTPConnection(host, int(port), timeout=timeout)
    try:
        conn.request("POST", "/v1/generate", body=json.dumps(body),
                     headers={"Content-Type": "application/json"})
        resp = conn.getresponse()
        return resp.status, resp.read().decode("utf-8", "replace")
    finally:
        conn.close()


def get(addr, path, timeout=30):
    host, port = addr.rsplit(":", 1)
    conn = http.client.HTTPConnection(host, int(port), timeout=timeout)
    try:
        conn.request("GET", path)
        resp = conn.getresponse()
        return resp.status, resp.read().decode("utf-8", "replace")
    finally:
        conn.close()


def wait_for_ready(proc, deadline_secs):
    """Read stdout (from a helper thread, so the wait really times out)
    until the readiness line appears."""
    found = {}

    def reader():
        for line in proc.stdout:
            line = line.strip()
            print(f"server stdout: {line}")
            if line.startswith("SERVE listening on "):
                found["addr"] = line[len("SERVE listening on "):]
                return

    t = threading.Thread(target=reader, daemon=True)
    t.start()
    t.join(deadline_secs)
    if "addr" not in found:
        if proc.poll() is not None:
            raise AssertionError(
                f"server exited early with code {proc.returncode}")
        raise AssertionError(f"no readiness line within {deadline_secs}s")
    return found["addr"]


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--bin", default="target/release/efla")
    ap.add_argument("--log", default="serve_smoke.log")
    ap.add_argument("--train-steps", type=int, default=5)
    ap.add_argument("--queue-depth", type=int, default=1)
    ap.add_argument("--startup-timeout", type=float, default=300.0)
    ap.add_argument("--client-timeout", type=float, default=120.0,
                    help="socket timeout of every generate call, seconds")
    args = ap.parse_args()
    global CLIENT_TIMEOUT
    CLIENT_TIMEOUT = args.client_timeout

    log = open(args.log, "w")
    cmd = [
        args.bin, "serve",
        "--listen", "127.0.0.1:0",
        "--steps", str(args.train_steps),
        "--corpus-bytes", "200000",
        "--queue-depth", str(args.queue_depth),
        "--drain-timeout", "30",
    ]
    print(f"launching: {' '.join(cmd)}")
    proc = subprocess.Popen(cmd, stdout=subprocess.PIPE, stderr=log, text=True)
    try:
        run_checks(proc, args)
    except BaseException:
        if proc.poll() is None:
            proc.kill()
        proc.wait()
        log.close()
        print(f"--- server log ({args.log}) ---")
        sys.stdout.write(open(args.log).read())
        raise
    log.close()
    print(f"all {len(CHECKS)} smoke checks passed")


def run_checks(proc, args):
    addr = wait_for_ready(proc, args.startup_timeout)
    print(f"server ready on {addr}")

    # 1. health + stats shape.
    status, body = get(addr, "/healthz")
    health = json.loads(body)
    check("healthz", status == 200 and health.get("ok") is True, body)
    status, body = get(addr, "/stats")
    stats = json.loads(body)
    slots = int(stats.get("slots", 0))
    check("stats", status == 200 and slots >= 1, body)
    check("stats schema_version", stats.get("schema_version") == 2,
          body[:200])

    # 1b. unified v1 error envelope: every non-2xx JSON answer carries
    # {"error": {"code", "message"[, "retry_after_ms"]}} with a stable
    # snake_case code.
    status, body = post_generate(addr, {"max_tokens": 4})
    err = json.loads(body).get("error", {})
    check("400 envelope",
          status == 400 and err.get("code") == "bad_request", body[:200])
    status, body = get(addr, "/nope")
    err = json.loads(body).get("error", {})
    check("404 envelope",
          status == 404 and err.get("code") == "not_found", body[:200])

    # 2. concurrent non-streamed generations. 429 is the documented
    # backpressure signal (the server runs with a tiny --queue-depth), so
    # clients retry on it; every request must eventually land a 200.
    results = {}

    def fire(i, max_tokens=12):
        body = {
            "prompt": f"smoke request {i} ",
            "max_tokens": max_tokens,
            "temperature": 0.0,
        }
        for _ in range(120):
            status, text = post_generate(addr, body)
            if status != 429:
                break
            time.sleep(0.25)
        results[i] = (status, text)

    threads = [threading.Thread(target=fire, args=(i,)) for i in range(8)]
    for t in threads:
        t.start()
    for t in threads:
        t.join()
    for i in range(8):
        status, body = results[i]
        check(f"concurrent generate {i}", status == 200, body[:200])
        payload = json.loads(body.splitlines()[-1])
        check(f"concurrent generate {i} tokens",
              len(payload["tokens"]) == 12, body[:200])

    # 3. streamed generation: token lines + final done line.
    status, body = post_generate(
        addr, {"prompt": "stream me ", "max_tokens": 6, "stream": True})
    check("stream status", status == 200, body[:200])
    lines = [json.loads(l) for l in body.splitlines() if l.strip()]
    check("stream line count", len(lines) == 7,
          f"{len(lines)} lines: {body[:200]}")
    final = lines[-1]
    streamed = [l["token"] for l in lines[:-1]]
    check("stream done marker", final.get("done") is True, body[:200])
    check("stream pieces match final", streamed == final["tokens"], body[:200])

    # 4. greedy determinism over the wire.
    _, a = post_generate(addr, {"prompt": "determinism", "max_tokens": 8})
    _, b = post_generate(addr, {"prompt": "determinism", "max_tokens": 8})
    ta = json.loads(a.splitlines()[-1])["tokens"]
    tb = json.loads(b.splitlines()[-1])["tokens"]
    check("greedy determinism", ta == tb, f"{ta} vs {tb}")

    # 5. queue overflow: burst of long generations past slots + queue.
    burst = slots + args.queue_depth + 11
    burst_results = {}

    def burst_fire(i):
        # Long generations so the slots stay busy for the whole burst:
        # the excess must observe a full queue, not a drained one.
        burst_results[i] = post_generate(
            addr, {"prompt": "overflow ", "max_tokens": 256})

    threads = [threading.Thread(target=burst_fire, args=(i,))
               for i in range(burst)]
    for t in threads:
        t.start()
    for t in threads:
        t.join()
    statuses = [burst_results[i][0] for i in range(burst)]
    check("overflow bursts 429", statuses.count(429) >= 1, f"{statuses}")
    body429 = next(burst_results[i][1] for i in range(burst)
                   if burst_results[i][0] == 429)
    err = json.loads(body429).get("error", {})
    check("429 envelope queue_full",
          err.get("code") == "queue_full"
          and err.get("retry_after_ms") == 1000, body429[:200])
    check("overflow still serves", statuses.count(200) >= 1, f"{statuses}")
    check("overflow only 200/429",
          all(s in (200, 429) for s in statuses), f"{statuses}")
    deadline = time.time() + 30
    recovered = 0
    while time.time() < deadline:
        status, _ = post_generate(addr, {"prompt": "recover", "max_tokens": 2})
        if status == 200:
            recovered = status
            break
        time.sleep(0.2)
    check("service recovers after overflow", recovered == 200)

    # 6. per-request deadline over the wire: the engine must abandon the
    # slot at timeout_ms with finish_reason "timeout" and partial tokens,
    # long before the absurd max_tokens budget would complete.
    t0 = time.time()
    status, body = post_generate(
        addr,
        {"prompt": "deadline me ", "max_tokens": 4096, "timeout_ms": 300},
        timeout=30)
    took = time.time() - t0
    check("deadline status", status == 200, body[:200])
    payload = json.loads(body.splitlines()[-1])
    check("deadline finish reason",
          payload.get("finish_reason") == "timeout", body[:200])
    check("deadline beats the budget",
          len(payload["tokens"]) < 4096 and took < 20.0,
          f"{len(payload['tokens'])} tokens in {took:.1f}s")

    # 7. SIGTERM drains in-flight work and exits cleanly. The two
    # requests are staggered so both are admitted (queue depth is tiny)
    # before the signal lands.
    inflight = {}

    def drain_fire(i):
        time.sleep(i * 0.1)
        inflight[i] = post_generate(
            addr, {"prompt": "drain me ", "max_tokens": 48})

    threads = [threading.Thread(target=drain_fire, args=(i,))
               for i in range(2)]
    for t in threads:
        t.start()
    time.sleep(0.5)
    proc.send_signal(signal.SIGTERM)
    for t in threads:
        t.join()
    for i in range(2):
        status, body = inflight[i]
        check(f"drained request {i}", status == 200, body[:200])
        payload = json.loads(body.splitlines()[-1])
        check(f"drained request {i} full budget",
              len(payload["tokens"]) == 48, body[:200])
    code = proc.wait(timeout=60)
    check("clean exit after SIGTERM", code == 0, f"exit code {code}")


if __name__ == "__main__":
    main()
