#!/usr/bin/env python3
"""Bench-regression gate for CI.

Reads the machine-readable ``BENCH {...}`` JSON lines emitted by
``cargo bench --bench kernel_throughput`` (one JSON object per line on
stdin or in the file given as argv[1]) and fails the job when a
performance invariant regresses:

* ``gemm_gflops``      — on a host with a SIMD tier (AVX-512, AVX2+FMA
  or NEON) the dispatched GEMM must not be slower than the scalar tier
  at the largest benched size (the whole point of the microkernel);
  smaller sizes only warn, since fast-mode iteration counts are noisy.
* ``serving_prefill``  — chunked parallel prefill must ingest prompts
  strictly faster than token-at-a-time decoding for every benched
  prompt length >= 64 (the serving acceptance bar).
* ``serving_cb``       — continuous batching over staggered arrivals
  must beat sequential one-request-at-a-time serving on aggregate
  tokens/s (the decode graph computes every slot row regardless, so
  a solo request wastes (batch-1)/batch of every step). The nested
  ``router`` object must show 3 single-thread replicas behind
  ``efla route`` out-serving 1 replica on aggregate tokens/s (the
  replica-sharding claim: O(1) decode state means capacity scales
  with replica count).
* ``serving_batched_decode`` — the slot-batched decode GEMM must be at
  least as fast as the per-slot single-row formulation at every point
  with >= 4 busy slots (the batched path packs the shared weight panel
  once instead of once per slot); busy=1 only warns, the two calls are
  the same work there.
* ``serving_state_cache`` — a turn that resumes from the session state
  cache prefills only the new tokens, so its TTFT must be strictly
  below the cold full-transcript replay at every conversation depth
  >= 1024 (shallow depths only warn: both paths prefill almost the
  same token count there, and fast-mode timings are noisy). The cached
  TTFT must also stay ~flat across depths — max/min > 5x fails, since
  a depth-dependent cached TTFT means the restore path is re-ingesting
  the transcript it claims to skip.
* ``serving_affinity`` — a turn-2 landing on the replica that parked
  the session state (affine) must have strictly lower TTFT than a
  session-blind landing (cold full-transcript replay) at every depth
  >= 1024 (shallow depths only warn). The failover path — wire-form
  state migration, then resume — only warns when it loses to blind:
  correctness is asserted in the bench, and migration cost is bounded
  by the O(d^2) state size, not the conversation depth.

Exit code 0 = all gates pass, 1 = regression, 2 = malformed input.
"""

import json
import sys


def fail(msg: str) -> None:
    print(f"GATE FAIL: {msg}")
    sys.exit(1)


def warn(msg: str) -> None:
    print(f"gate warn: {msg}")


def gate_gemm(obj: dict) -> None:
    kernel = obj.get("kernel", "")
    points = obj.get("points", [])
    if not points:
        fail("gemm_gflops: no measurement points")
    if kernel not in ("Avx512", "Avx2Fma", "Neon"):
        warn(f"gemm_gflops: dispatched tier is {kernel!r}, skipping speedup gate")
        return
    largest = max(points, key=lambda p: p.get("size", 0))
    for p in points:
        size = p.get("size")
        speedup = p.get("speedup", 0.0)
        line = f"gemm {size}^3: dispatched/scalar speedup {speedup:.2f}x"
        if p is largest and speedup < 1.0:
            fail(f"{line} — dispatched GEMM tier is slower than scalar")
        if speedup < 1.0:
            warn(f"{line} (sub-gate size, not fatal)")
        else:
            print(f"gate ok: {line}")


def gate_serving(obj: dict) -> None:
    points = obj.get("points", [])
    if not points:
        fail("serving_prefill: no measurement points")
    for p in points:
        plen = p.get("prompt_len", 0)
        pre = p.get("prefill_tokens_per_sec", 0.0)
        tat = p.get("token_at_a_time_tokens_per_sec", 0.0)
        line = f"serving prompt_len={plen}: prefill {pre:.0f} tok/s vs token-at-a-time {tat:.0f} tok/s"
        if plen >= 64 and pre <= tat:
            fail(f"{line} — chunked prefill must be strictly faster")
        print(f"gate ok: {line}")


def gate_serving_cb(obj: dict) -> None:
    cb = obj.get("cb_tokens_per_sec", 0.0)
    seq = obj.get("sequential_tokens_per_sec", 0.0)
    if cb <= 0.0 or seq <= 0.0:
        fail(f"serving_cb: missing throughput measurements (cb={cb}, seq={seq})")
    line = f"serving_cb: continuous {cb:.0f} tok/s vs sequential {seq:.0f} tok/s"
    if cb <= seq:
        fail(f"{line} — continuous batching must beat one-request-at-a-time")
    print(f"gate ok: {line} ({cb / seq:.2f}x)")
    router = obj.get("router")
    if not isinstance(router, dict):
        fail("serving_cb: missing nested 'router' measurements")
    one = router.get("replicas_1_tok_s", 0.0)
    three = router.get("replicas_3_tok_s", 0.0)
    if one <= 0.0 or three <= 0.0:
        fail(f"serving_cb router: missing throughput measurements (1={one}, 3={three})")
    line = f"serving_cb router: 3 replicas {three:.0f} tok/s vs 1 replica {one:.0f} tok/s"
    if three <= one:
        fail(f"{line} — replica sharding must raise aggregate throughput")
    print(f"gate ok: {line} ({three / one:.2f}x)")


def gate_serving_batched(obj: dict) -> None:
    points = obj.get("points", [])
    if not points:
        fail("serving_batched_decode: no measurement points")
    for p in points:
        busy = p.get("busy", 0)
        batched = p.get("batched_tok_s", 0.0)
        gemv = p.get("gemv_tok_s", 0.0)
        line = f"batched decode busy={busy}: batched {batched:.0f} tok/s vs per-slot GEMV {gemv:.0f} tok/s"
        if batched <= 0.0 or gemv <= 0.0:
            fail(f"{line} — missing throughput measurements")
        if busy >= 4 and batched < gemv:
            fail(f"{line} — batched GEMM must not lose to per-slot GEMV at >= 4 slots")
        if batched < gemv:
            warn(f"{line} (busy=1 is the same work both ways, not fatal)")
        else:
            print(f"gate ok: {line}")


def gate_state_cache(obj: dict) -> None:
    points = obj.get("points", [])
    if not points:
        fail("serving_state_cache: no measurement points")
    cached = []
    for p in points:
        depth = p.get("depth", 0)
        hot = p.get("cached_ttft_ms", 0.0)
        cold = p.get("cold_ttft_ms", 0.0)
        line = (f"state cache depth={depth}: cached TTFT {hot:.2f} ms "
                f"vs cold replay {cold:.2f} ms")
        if hot <= 0.0 or cold <= 0.0:
            fail(f"{line} — missing TTFT measurements")
        cached.append(hot)
        if depth >= 1024 and hot >= cold:
            fail(f"{line} — cached resume must beat cold replay at depth >= 1024")
        if hot >= cold:
            warn(f"{line} (shallow depth, not fatal)")
        else:
            print(f"gate ok: {line} ({cold / hot:.2f}x)")
    spread = max(cached) / min(cached)
    line = f"state cache: cached TTFT spread across depths {spread:.2f}x"
    if spread > 5.0:
        fail(f"{line} — cached TTFT must stay ~flat in conversation depth")
    print(f"gate ok: {line}")


def gate_serving_affinity(obj: dict) -> None:
    points = obj.get("points", [])
    if not points:
        fail("serving_affinity: no measurement points")
    for p in points:
        depth = p.get("depth", 0)
        affine = p.get("affine_ttft_ms", 0.0)
        blind = p.get("blind_ttft_ms", 0.0)
        failover = p.get("failover_ttft_ms", 0.0)
        line = (f"affinity depth={depth}: affine TTFT {affine:.2f} ms "
                f"vs blind {blind:.2f} ms vs failover {failover:.2f} ms")
        if affine <= 0.0 or blind <= 0.0 or failover <= 0.0:
            fail(f"{line} — missing TTFT measurements")
        if depth >= 1024 and affine >= blind:
            fail(f"{line} — affine landing must beat session-blind at depth >= 1024")
        if affine >= blind:
            warn(f"{line} (shallow depth, not fatal)")
        else:
            print(f"gate ok: {line} ({blind / affine:.2f}x)")
        if failover >= blind:
            warn(f"{line} — migration not cheaper than cold replay here (not fatal)")


def main() -> None:
    src = open(sys.argv[1]) if len(sys.argv) > 1 else sys.stdin
    seen = set()
    for raw in src:
        raw = raw.strip()
        if not raw:
            continue
        if raw.startswith("BENCH "):
            raw = raw[len("BENCH "):]
        try:
            obj = json.loads(raw)
        except json.JSONDecodeError as e:
            print(f"malformed BENCH line: {e}: {raw[:120]}")
            sys.exit(2)
        name = obj.get("bench")
        seen.add(name)
        if name == "gemm_gflops":
            gate_gemm(obj)
        elif name == "serving_prefill":
            gate_serving(obj)
        elif name == "serving_cb":
            gate_serving_cb(obj)
        elif name == "serving_batched_decode":
            gate_serving_batched(obj)
        elif name == "serving_state_cache":
            gate_state_cache(obj)
        elif name == "serving_affinity":
            gate_serving_affinity(obj)
    for required in ("gemm_gflops", "serving_prefill", "serving_cb",
                     "serving_batched_decode", "serving_state_cache",
                     "serving_affinity"):
        if required not in seen:
            fail(f"required bench section {required!r} missing from BENCH output")
    print("all bench gates passed")


if __name__ == "__main__":
    main()
