#!/usr/bin/env python3
"""Chaos smoke of `efla route` for CI: replica failure must be invisible.

Launches three `efla serve` replicas (untrained, single compute thread,
so all three hold bit-identical weights) and an `efla route` front end
over them, then:

1.  records a healthy greedy reference by hitting ONE replica directly —
    the single-engine ground truth every routed answer must match;
2.  drives concurrent load through the router while injecting faults into
    replica 0: first a 2s per-request stall (via its `POST /fault`
    endpoint — the replica keeps running, its health probes start
    failing), then SIGKILL mid-run;
3.  asserts ZERO client-visible failures: every request returns 200 —
    after client-side retries of the deliberate 503 shed signal — with
    tokens bit-identical to the reference;
4.  asserts the router's aggregated `/stats` accounts for the chaos:
    retries >= 1 (in-flight work on the killed replica failed over),
    ejections >= 1 (the breaker took replica 0 out), shed == the 503s
    the clients saw, and failed == timeouts == 0;
5.  SIGTERMs the router and the surviving replicas and requires exit 0.

Stderr of every process goes to the log file given by ``--log``.
Exit code 0 = all checks pass.

Reproduce locally:
    cargo build --release
    python3 scripts/route_chaos.py --bin target/release/efla
"""

import argparse
import http.client
import json
import signal
import subprocess
import sys
import threading
import time

CHECKS = []


def check(name, ok, detail=""):
    CHECKS.append((name, ok))
    mark = "ok" if ok else "FAIL"
    print(f"chaos {mark}: {name}" + (f" — {detail}" if detail else ""))
    if not ok:
        raise AssertionError(f"{name}: {detail}")


def request(addr, method, path, body=None, timeout=30.0):
    host, port = addr.rsplit(":", 1)
    conn = http.client.HTTPConnection(host, int(port), timeout=timeout)
    try:
        conn.request(method, path, body=body,
                     headers={"Content-Type": "application/json"})
        resp = conn.getresponse()
        return resp.status, resp.read().decode("utf-8", "replace")
    finally:
        conn.close()


def wait_for_line(proc, prefix, deadline_secs, name):
    """Read a process's stdout on a helper thread until `prefix` appears."""
    found = {}

    def reader():
        for line in proc.stdout:
            line = line.strip()
            print(f"{name} stdout: {line}")
            if line.startswith(prefix):
                found["rest"] = line[len(prefix):]
                return

    t = threading.Thread(target=reader, daemon=True)
    t.start()
    t.join(deadline_secs)
    if "rest" not in found:
        if proc.poll() is not None:
            raise AssertionError(f"{name} exited early: {proc.returncode}")
        raise AssertionError(f"{name}: no '{prefix}' line in {deadline_secs}s")
    return found["rest"]


def prompt_of(i):
    # A small rotating prompt set, so the chaos pass replays prompts the
    # reference pass measured.
    return f"chaos probe {i % 8} "


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--bin", default="target/release/efla")
    ap.add_argument("--log", default="route_chaos.log")
    ap.add_argument("--requests", type=int, default=36)
    ap.add_argument("--clients", type=int, default=4)
    ap.add_argument("--max-tokens", type=int, default=8)
    ap.add_argument("--startup-timeout", type=float, default=120.0)
    args = ap.parse_args()

    log = open(args.log, "w")
    procs = {}
    try:
        run_chaos(args, log, procs)
    except BaseException:
        for p in procs.values():
            if p.poll() is None:
                p.kill()
        for p in procs.values():
            p.wait()
        log.close()
        print(f"--- process log ({args.log}) ---")
        sys.stdout.write(open(args.log).read())
        raise
    log.close()
    print(f"all {len(CHECKS)} chaos checks passed")


def run_chaos(args, log, procs):
    # Untrained + --threads 1: every replica derives bit-identical weights
    # from the shared family seed, which is what makes cross-replica
    # greedy determinism checkable at all.
    replica_addrs = []
    for i in range(3):
        cmd = [args.bin, "serve", "--listen", "127.0.0.1:0", "--steps", "0",
               "--threads", "1", "--queue-depth", "8", "--drain-timeout", "30"]
        proc = subprocess.Popen(cmd, stdout=subprocess.PIPE, stderr=log,
                                text=True)
        procs[f"replica{i}"] = proc
        addr = wait_for_line(proc, "SERVE listening on ",
                             args.startup_timeout, f"replica{i}")
        replica_addrs.append(addr)
        print(f"replica {i} on {addr}")

    cmd = [args.bin, "route", "--listen", "127.0.0.1:0",
           "--backends", ",".join(replica_addrs),
           "--health-interval-ms", "50", "--cooldown-ms", "500"]
    router = subprocess.Popen(cmd, stdout=subprocess.PIPE, stderr=log,
                              text=True)
    procs["router"] = router
    raddr = wait_for_line(router, "ROUTE listening on ",
                          args.startup_timeout, "router")
    print(f"router on {raddr}")

    # Wait until the prober has seen all three replicas.
    deadline = time.time() + 30
    while True:
        status, body = request(raddr, "GET", "/stats")
        stats = json.loads(body)
        probed = sum(1 for r in stats["replicas"] if r["probes_ok"] >= 1)
        if status == 200 and probed == 3:
            break
        if time.time() > deadline:
            raise AssertionError(f"replicas never probed healthy: {body}")
        time.sleep(0.1)
    status, body = request(raddr, "GET", "/healthz")
    health = json.loads(body)
    check("router healthz", status == 200 and health.get("available") == 3,
          body)

    # 1. Healthy single-engine reference: greedy tokens per prompt, from
    # one replica directly (no router in the path).
    reference = {}
    for i in range(8):
        payload = json.dumps({"id": 1000 + i, "prompt": prompt_of(i),
                              "max_tokens": args.max_tokens})
        status, body = request(replica_addrs[1], "POST", "/v1/generate",
                               payload, timeout=60)
        check(f"reference {i}", status == 200, body[:200])
        reference[i % 8] = json.loads(body)["tokens"]

    # 2. Concurrent load through the router with a mid-run stall + kill of
    # replica 0. Clients retry the documented backpressure signals (503
    # shed / 429) and transient connection errors; anything else is a
    # client-visible failure and fails the smoke.
    results = {}
    shed_seen = [0]
    lock = threading.Lock()
    next_id = [0]

    def one_request(rid):
        payload = json.dumps({"id": rid, "prompt": prompt_of(rid),
                              "max_tokens": args.max_tokens})
        for _ in range(200):
            try:
                status, body = request(raddr, "POST", "/v1/generate",
                                       payload, timeout=60)
            except OSError:
                time.sleep(0.1)
                continue
            if status == 503:
                with lock:
                    shed_seen[0] += 1
                time.sleep(0.2)
                continue
            if status == 429:
                time.sleep(0.2)
                continue
            return status, body
        return None, "retries exhausted"

    def client():
        while True:
            with lock:
                rid = next_id[0]
                if rid >= args.requests:
                    return
                next_id[0] += 1
            results[rid] = one_request(rid)

    threads = [threading.Thread(target=client) for _ in range(args.clients)]
    for t in threads:
        t.start()

    # Let some healthy traffic through, then stall replica 0 (probes start
    # timing out, in-flight requests hang)...
    while True:
        with lock:
            if next_id[0] >= 6:
                break
        time.sleep(0.05)
    status, body = request(replica_addrs[0], "POST", "/fault",
                           "stall_ms=2000")
    check("fault armed on replica 0", status == 200, body)
    time.sleep(0.7)
    # ...then kill it outright mid-run.
    procs["replica0"].kill()
    print("replica 0 killed")
    for t in threads:
        t.join()

    # 3. Zero client-visible failures, bit-identical outputs.
    for rid in range(args.requests):
        status, body = results[rid]
        check(f"request {rid} completes", status == 200, str(body)[:200])
        tokens = json.loads(body)["tokens"]
        check(f"request {rid} bit-identical",
              tokens == reference[rid % 8],
              f"{tokens} vs reference {reference[rid % 8]}")

    # 4. The router's stats must account for the chaos.
    deadline = time.time() + 20
    while True:
        status, body = request(raddr, "GET", "/stats")
        stats = json.loads(body)
        state0 = stats["replicas"][0]["state"]
        if state0 == "ejected":
            break
        if time.time() > deadline:
            raise AssertionError(f"replica 0 never ejected: {body}")
        time.sleep(0.1)
    check("stats: killed replica ejected", True, f"state={state0}")
    check("stats: retries counted", stats["retries"] >= 1, body[:400])
    check("stats: ejections counted", stats["ejections"] >= 1, body[:400])
    check("stats: shed accounting", stats["shed"] == shed_seen[0],
          f"router shed {stats['shed']} vs client 503s {shed_seen[0]}")
    check("stats: no hard failures",
          stats["failed"] == 0 and stats["timeouts"] == 0, body[:400])
    check("stats: aggregate present",
          stats["aggregate"]["tokens_processed"] >= 1, body[:400])

    # 5. Clean shutdown: router first, then the surviving replicas.
    router = procs["router"]
    router.send_signal(signal.SIGTERM)
    code = router.wait(timeout=60)
    check("router exit 0 on SIGTERM", code == 0, f"exit code {code}")
    for i in (1, 2):
        p = procs[f"replica{i}"]
        p.send_signal(signal.SIGTERM)
        code = p.wait(timeout=60)
        check(f"replica {i} exit 0 on SIGTERM", code == 0, f"exit {code}")
    procs["replica0"].wait()


if __name__ == "__main__":
    main()
