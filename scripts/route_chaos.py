#!/usr/bin/env python3
"""Chaos smoke of `efla route` for CI: replica failure must be invisible.

Launches three `efla serve` replicas (untrained, single compute thread,
so all three hold bit-identical weights) and an `efla route` front end
over them, then:

1.  records a healthy greedy reference by hitting ONE replica directly —
    the single-engine ground truth every routed answer must match;
2.  drives concurrent load through the router while injecting faults into
    replica 0: first a 2s per-request stall (via its `POST /fault`
    endpoint — the replica keeps running, its health probes start
    failing), then SIGKILL mid-run;
3.  asserts ZERO client-visible failures: every request returns 200 —
    after client-side retries of the deliberate 503 shed signal — with
    tokens bit-identical to the reference;
4.  asserts the router's aggregated `/stats` accounts for the chaos:
    retries >= 1 (in-flight work on the killed replica failed over),
    ejections >= 1 (the breaker took replica 0 out), shed == the 503s
    the clients saw, and failed == timeouts == 0;
5.  SIGTERMs the router and the surviving replicas and requires exit 0;
6.  re-launches a cache-armed cluster (``--state-cache-bytes``) and
    drives 3-turn sessions through the affine router, SIGKILLing the
    rendezvous home replica mid-conversation: every turn must still
    answer 200 with tokens bit-identical to a cold single-engine
    reference, and the router ``/stats`` must account for every
    affinity hit, fallback and state migration EXACTLY — including one
    successful migration off a merely *stalled* (ejected but still
    reachable) replica.

Along the way every non-2xx JSON answer is checked against the unified
v1 error envelope ``{"error": {"code", "message"[, "retry_after_ms"]}}``
and both stats surfaces against ``"schema_version": 2``.

Stderr of every process goes to the log file given by ``--log``.
Exit code 0 = all checks pass.

Reproduce locally:
    cargo build --release
    python3 scripts/route_chaos.py --bin target/release/efla
"""

import argparse
import http.client
import json
import signal
import subprocess
import sys
import threading
import time

CHECKS = []


def check(name, ok, detail=""):
    CHECKS.append((name, ok))
    mark = "ok" if ok else "FAIL"
    print(f"chaos {mark}: {name}" + (f" — {detail}" if detail else ""))
    if not ok:
        raise AssertionError(f"{name}: {detail}")


def request(addr, method, path, body=None, timeout=30.0):
    host, port = addr.rsplit(":", 1)
    conn = http.client.HTTPConnection(host, int(port), timeout=timeout)
    try:
        conn.request(method, path, body=body,
                     headers={"Content-Type": "application/json"})
        resp = conn.getresponse()
        return resp.status, resp.read().decode("utf-8", "replace")
    finally:
        conn.close()


def wait_for_line(proc, prefix, deadline_secs, name):
    """Read a process's stdout on a helper thread until `prefix` appears."""
    found = {}

    def reader():
        for line in proc.stdout:
            line = line.strip()
            print(f"{name} stdout: {line}")
            if line.startswith(prefix):
                found["rest"] = line[len(prefix):]
                return

    t = threading.Thread(target=reader, daemon=True)
    t.start()
    t.join(deadline_secs)
    if "rest" not in found:
        if proc.poll() is not None:
            raise AssertionError(f"{name} exited early: {proc.returncode}")
        raise AssertionError(f"{name}: no '{prefix}' line in {deadline_secs}s")
    return found["rest"]


def prompt_of(i):
    # A small rotating prompt set, so the chaos pass replays prompts the
    # reference pass measured.
    return f"chaos probe {i % 8} "


FNV_OFFSET = 0xCBF29CE484222325
FNV_PRIME = 0x100000001B3
MASK64 = (1 << 64) - 1


def fnv1a(data):
    """Python mirror of the Rust state cache's FNV-1a (64-bit)."""
    h = FNV_OFFSET
    for b in data:
        h = ((h ^ b) * FNV_PRIME) & MASK64
    return h


def rendezvous_home(session, addrs):
    """Mirror of the router's rendezvous pick: FNV-1a over
    ``session/addr``, highest score wins, lowest index on ties."""
    best, best_score = 0, -1
    for i, addr in enumerate(addrs):
        score = fnv1a(f"{session}/{addr}".encode())
        if score > best_score:
            best, best_score = i, score
    return best


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--bin", default="target/release/efla")
    ap.add_argument("--log", default="route_chaos.log")
    ap.add_argument("--requests", type=int, default=36)
    ap.add_argument("--clients", type=int, default=4)
    ap.add_argument("--max-tokens", type=int, default=8)
    ap.add_argument("--startup-timeout", type=float, default=120.0)
    args = ap.parse_args()

    log = open(args.log, "w")
    procs = {}
    try:
        run_chaos(args, log, procs)
        run_session_phase(args, log, procs)
    except BaseException:
        for p in procs.values():
            if p.poll() is None:
                p.kill()
        for p in procs.values():
            p.wait()
        log.close()
        print(f"--- process log ({args.log}) ---")
        sys.stdout.write(open(args.log).read())
        raise
    log.close()
    print(f"all {len(CHECKS)} chaos checks passed")


def run_chaos(args, log, procs):
    # Untrained + --threads 1: every replica derives bit-identical weights
    # from the shared family seed, which is what makes cross-replica
    # greedy determinism checkable at all.
    replica_addrs = []
    for i in range(3):
        cmd = [args.bin, "serve", "--listen", "127.0.0.1:0", "--steps", "0",
               "--threads", "1", "--queue-depth", "8", "--drain-timeout", "30"]
        proc = subprocess.Popen(cmd, stdout=subprocess.PIPE, stderr=log,
                                text=True)
        procs[f"replica{i}"] = proc
        addr = wait_for_line(proc, "SERVE listening on ",
                             args.startup_timeout, f"replica{i}")
        replica_addrs.append(addr)
        print(f"replica {i} on {addr}")

    cmd = [args.bin, "route", "--listen", "127.0.0.1:0",
           "--backends", ",".join(replica_addrs),
           "--health-interval-ms", "50", "--cooldown-ms", "500"]
    router = subprocess.Popen(cmd, stdout=subprocess.PIPE, stderr=log,
                              text=True)
    procs["router"] = router
    raddr = wait_for_line(router, "ROUTE listening on ",
                          args.startup_timeout, "router")
    print(f"router on {raddr}")

    # Wait until the prober has seen all three replicas.
    deadline = time.time() + 30
    while True:
        status, body = request(raddr, "GET", "/stats")
        stats = json.loads(body)
        probed = sum(1 for r in stats["replicas"] if r["probes_ok"] >= 1)
        if status == 200 and probed == 3:
            break
        if time.time() > deadline:
            raise AssertionError(f"replicas never probed healthy: {body}")
        time.sleep(0.1)
    check("router stats schema_version", stats.get("schema_version") == 2,
          body[:400])
    status, body = request(raddr, "GET", "/healthz")
    health = json.loads(body)
    check("router healthz", status == 200 and health.get("available") == 3,
          body)
    # Unknown routes answer the unified v1 error envelope.
    status, body = request(raddr, "GET", "/nope")
    err = json.loads(body).get("error", {})
    check("router 404 envelope",
          status == 404 and err.get("code") == "not_found", body[:200])
    status, body = request(replica_addrs[1], "POST", "/v1/generate",
                           "not json")
    err = json.loads(body).get("error", {})
    check("replica 400 envelope",
          status == 400 and err.get("code") == "bad_request", body[:200])

    # 1. Healthy single-engine reference: greedy tokens per prompt, from
    # one replica directly (no router in the path).
    reference = {}
    for i in range(8):
        payload = json.dumps({"id": 1000 + i, "prompt": prompt_of(i),
                              "max_tokens": args.max_tokens})
        status, body = request(replica_addrs[1], "POST", "/v1/generate",
                               payload, timeout=60)
        check(f"reference {i}", status == 200, body[:200])
        reference[i % 8] = json.loads(body)["tokens"]

    # 2. Concurrent load through the router with a mid-run stall + kill of
    # replica 0. Clients retry the documented backpressure signals (503
    # shed / 429) and transient connection errors; anything else is a
    # client-visible failure and fails the smoke.
    results = {}
    shed_seen = [0]
    shed_body = [None]
    lock = threading.Lock()
    next_id = [0]

    def one_request(rid):
        payload = json.dumps({"id": rid, "prompt": prompt_of(rid),
                              "max_tokens": args.max_tokens})
        for _ in range(200):
            try:
                status, body = request(raddr, "POST", "/v1/generate",
                                       payload, timeout=60)
            except OSError:
                time.sleep(0.1)
                continue
            if status == 503:
                with lock:
                    shed_seen[0] += 1
                    shed_body[0] = body
                time.sleep(0.2)
                continue
            if status == 429:
                time.sleep(0.2)
                continue
            return status, body
        return None, "retries exhausted"

    def client():
        while True:
            with lock:
                rid = next_id[0]
                if rid >= args.requests:
                    return
                next_id[0] += 1
            results[rid] = one_request(rid)

    threads = [threading.Thread(target=client) for _ in range(args.clients)]
    for t in threads:
        t.start()

    # Let some healthy traffic through, then stall replica 0 (probes start
    # timing out, in-flight requests hang)...
    while True:
        with lock:
            if next_id[0] >= 6:
                break
        time.sleep(0.05)
    status, body = request(replica_addrs[0], "POST", "/fault",
                           "stall_ms=2000")
    check("fault armed on replica 0", status == 200, body)
    time.sleep(0.7)
    # ...then kill it outright mid-run.
    procs["replica0"].kill()
    print("replica 0 killed")
    for t in threads:
        t.join()

    # 3. Zero client-visible failures, bit-identical outputs.
    for rid in range(args.requests):
        status, body = results[rid]
        check(f"request {rid} completes", status == 200, str(body)[:200])
        tokens = json.loads(body)["tokens"]
        check(f"request {rid} bit-identical",
              tokens == reference[rid % 8],
              f"{tokens} vs reference {reference[rid % 8]}")

    # 4. The router's stats must account for the chaos.
    deadline = time.time() + 20
    while True:
        status, body = request(raddr, "GET", "/stats")
        stats = json.loads(body)
        state0 = stats["replicas"][0]["state"]
        if state0 == "ejected":
            break
        if time.time() > deadline:
            raise AssertionError(f"replica 0 never ejected: {body}")
        time.sleep(0.1)
    check("stats: killed replica ejected", True, f"state={state0}")
    check("stats: retries counted", stats["retries"] >= 1, body[:400])
    check("stats: ejections counted", stats["ejections"] >= 1, body[:400])
    check("stats: shed accounting", stats["shed"] == shed_seen[0],
          f"router shed {stats['shed']} vs client 503s {shed_seen[0]}")
    if shed_seen[0]:
        err = json.loads(shed_body[0]).get("error", {})
        check("shed 503 envelope",
              err.get("code") == "replicas_saturated"
              and err.get("retry_after_ms") == 1000,
              str(shed_body[0])[:200])
    check("stats: no hard failures",
          stats["failed"] == 0 and stats["timeouts"] == 0, body[:400])
    check("stats: aggregate present",
          stats["aggregate"]["tokens_processed"] >= 1, body[:400])

    # 5. Clean shutdown: router first, then the surviving replicas.
    router = procs["router"]
    router.send_signal(signal.SIGTERM)
    code = router.wait(timeout=60)
    check("router exit 0 on SIGTERM", code == 0, f"exit code {code}")
    for i in (1, 2):
        p = procs[f"replica{i}"]
        p.send_signal(signal.SIGTERM)
        code = p.wait(timeout=60)
        check(f"replica {i} exit 0 on SIGTERM", code == 0, f"exit {code}")
    procs["replica0"].wait()


def run_session_phase(args, log, procs):
    """Multi-turn conversations through the session-affine router.

    Kills the rendezvous home replica mid-conversation and requires
    zero client-visible failures, bit-identical greedy outputs, and
    EXACT affinity/migration accounting on the router's /stats — then a
    stall sub-phase where the ejected-but-reachable source replica lets
    the state migration actually succeed.
    """
    replica_addrs = []
    for i in range(3):
        cmd = [args.bin, "serve", "--listen", "127.0.0.1:0", "--steps", "0",
               "--threads", "1", "--queue-depth", "8", "--drain-timeout", "30",
               "--state-cache-bytes", "8388608"]
        proc = subprocess.Popen(cmd, stdout=subprocess.PIPE, stderr=log,
                                text=True)
        procs[f"s-replica{i}"] = proc
        addr = wait_for_line(proc, "SERVE listening on ",
                             args.startup_timeout, f"s-replica{i}")
        replica_addrs.append(addr)
        print(f"session replica {i} on {addr}")

    cmd = [args.bin, "route", "--listen", "127.0.0.1:0",
           "--backends", ",".join(replica_addrs),
           "--health-interval-ms", "50", "--cooldown-ms", "500"]
    router = subprocess.Popen(cmd, stdout=subprocess.PIPE, stderr=log,
                              text=True)
    procs["s-router"] = router
    raddr = wait_for_line(router, "ROUTE listening on ",
                          args.startup_timeout, "s-router")
    print(f"session router on {raddr}")

    deadline = time.time() + 30
    while True:
        status, body = request(raddr, "GET", "/stats")
        stats = json.loads(body)
        probed = sum(1 for r in stats["replicas"] if r["probes_ok"] >= 1)
        if status == 200 and probed == 3:
            break
        if time.time() > deadline:
            raise AssertionError(f"replicas never probed healthy: {body}")
        time.sleep(0.1)

    def wait_ejected(idx):
        deadline = time.time() + 20
        while True:
            _, body = request(raddr, "GET", "/stats")
            state = json.loads(body)["replicas"][idx]["state"]
            if state == "ejected":
                return
            if time.time() > deadline:
                raise AssertionError(f"replica {idx} never ejected: {body}")
            time.sleep(0.05)

    # Pick sessions by their rendezvous home: two homed on replica 0
    # (which we will SIGKILL) and one each on the survivors. The Python
    # mirror MUST agree with the router's Rust hash, or the counters
    # below drift — that agreement is itself under test.
    by_home = {0: [], 1: [], 2: []}
    i = 0
    while len(by_home[0]) < 2 or not by_home[1] or not by_home[2]:
        sid = f"chat-{i}"
        home = rendezvous_home(sid, replica_addrs)
        want = 2 if home == 0 else 1
        if len(by_home[home]) < want:
            by_home[home].append(sid)
        i += 1
    sessions = by_home[0] + by_home[1] + by_home[2]
    prompts = {sid: [ord(c) for c in f"session {sid} "] for sid in sessions}
    print(f"sessions by home: {by_home}")

    def turn(sid):
        # Cold single-engine reference first: replica 2 direct, no
        # session_id, so its cache counters stay untouched.
        payload = json.dumps({"tokens": prompts[sid], "max_tokens": 6})
        status, body = request(replica_addrs[2], "POST", "/v1/generate",
                               payload, timeout=60)
        check(f"{sid} reference", status == 200, str(body)[:200])
        ref = json.loads(body)["tokens"]
        payload = json.dumps({"tokens": prompts[sid], "max_tokens": 6,
                              "session_id": sid})
        status, body = request(raddr, "POST", "/v1/generate", payload,
                               timeout=60)
        check(f"{sid} turn answers 200", status == 200, str(body)[:200])
        toks = json.loads(body)["tokens"]
        check(f"{sid} turn bit-identical", toks == ref,
              f"{toks} vs reference {ref}")
        # Extend the transcript past the cached prefix for the next turn.
        prompts[sid] = prompts[sid] + toks + [9]

    # Turn 1: every session lands on its home (affinity hits only).
    for sid in sessions:
        turn(sid)

    procs["s-replica0"].kill()
    print("session replica 0 killed")
    wait_ejected(0)

    # Turns 2 and 3: home-0 sessions fall back least-loaded. On turn 2
    # the router tries to migrate their state off dead replica 0 and
    # fails (cold prefill instead); on turn 3 they are already parked on
    # the fallback, so no migration is attempted. Survivor-homed
    # sessions keep hitting their home.
    for _ in (2, 3):
        for sid in sessions:
            turn(sid)

    n0 = len(by_home[0])
    n_other = len(sessions) - n0
    status, body = request(raddr, "GET", "/stats")
    stats = json.loads(body)
    routing = stats["routing"]
    check("session stats schema_version", stats.get("schema_version") == 2,
          body[:400])
    check("routing: affinity accounting",
          routing["affinity_hits"] == len(sessions) + 2 * n_other
          and routing["affinity_fallbacks"] == 2 * n0,
          f"want hits={len(sessions) + 2 * n_other} "
          f"fallbacks={2 * n0}, got {routing}")
    check("routing: dead-source migrations fail into cold prefill",
          routing["migrations_ok"] == 0
          and routing["migrations_failed"] == n0,
          f"want failed={n0}, got {routing}")

    # Stall sub-phase: eject replica 1 while leaving it reachable — the
    # fallback for the session homed (and last landed) there must now
    # MIGRATE its parked state to replica 2 instead of cold-prefilling.
    status, body = request(replica_addrs[1], "POST", "/fault",
                           "stall_ms=2000")
    check("stall armed on session replica 1", status == 200, body)
    wait_ejected(1)
    turn(by_home[1][0])
    status, body = request(raddr, "GET", "/stats")
    routing = json.loads(body)["routing"]
    check("routing: stalled-source migration succeeds",
          routing["migrations_ok"] == 1
          and routing["migrations_failed"] == n0
          and routing["affinity_fallbacks"] == 2 * n0 + 1,
          f"want ok=1 failed={n0}, got {routing}")

    # Replica 2's own cache proves the handoffs: its homed session
    # missed once (turn 1) then hit twice, and the migrated session hit
    # once more. Poll — the engine publishes stats a beat after
    # answering.
    deadline = time.time() + 20
    while True:
        status, body = request(replica_addrs[2], "GET", "/stats")
        rstats = json.loads(body)
        if rstats["state_cache"]["hits"] >= 3:
            break
        if time.time() > deadline:
            raise AssertionError(f"cache hits never reached 3: {body}")
        time.sleep(0.1)
    check("replica stats schema_version",
          rstats.get("schema_version") == 2, body[:400])
    check("replica 2 cache accounting",
          rstats["state_cache"]["hits"] == 3
          and rstats["state_cache"]["misses"] == 1,
          str(rstats["state_cache"]))

    # Clear the stall so replica 1 can drain cleanly, then shut down.
    status, _ = request(replica_addrs[1], "POST", "/fault", "", timeout=10)
    check("stall cleared on session replica 1", status == 200)
    router.send_signal(signal.SIGTERM)
    code = router.wait(timeout=60)
    check("session router exit 0 on SIGTERM", code == 0, f"exit {code}")
    for i in (1, 2):
        p = procs[f"s-replica{i}"]
        p.send_signal(signal.SIGTERM)
        code = p.wait(timeout=60)
        check(f"session replica {i} exit 0 on SIGTERM", code == 0,
              f"exit {code}")
    procs["s-replica0"].wait()


if __name__ == "__main__":
    main()
