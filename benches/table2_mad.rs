//! Bench: Table 2 — the MAD synthetic benchmark, DeltaNet vs EFLA.
//!
//! Six token-manipulation tasks (compress, fuzzy recall, in-context recall,
//! memorize, noisy recall, selective copy), one small model trained per
//! (task, mixer) with identical budgets; reports masked answer accuracy.
//!
//! Expected shape (paper Table 2): EFLA >= DeltaNet on most tasks, clearest
//! on memorize / noisy recall.
//!
//! Env knobs: EFLA_T2_STEPS (default 30), EFLA_T2_EVAL (default 4).

use efla::coordinator::experiments::mad_run;
use efla::data::mad::MadTask;
use efla::runtime::open_backend;
use efla::util::bench::Table;
use efla::util::json::{self, Json};

fn env_u64(name: &str, default: u64) -> u64 {
    std::env::var(name).ok().and_then(|v| v.parse().ok()).unwrap_or(default)
}

fn main() {
    efla::util::logging::init();
    let steps = env_u64("EFLA_T2_STEPS", 16);
    let eval_batches = env_u64("EFLA_T2_EVAL", 4) as usize;
    let backend = open_backend(std::path::Path::new("artifacts")).expect("open backend");
    for m in ["efla", "deltanet"] {
        if !backend.has_family(&format!("lm_mad_{m}")) {
            eprintln!("backend cannot build lm_mad_{m}");
            std::process::exit(1);
        }
    }

    println!("## Table 2 (scaled): MAD suite, {steps} steps per (task, mixer)\n");
    let mut t = Table::new(&[
        "model", "compress", "fuzzy", "in-ctx", "memorize", "noisy", "sel-copy", "avg",
    ]);
    let mut out_rows = Vec::new();
    for mixer in ["deltanet", "efla"] {
        let mut accs = Vec::new();
        for task in MadTask::all() {
            let acc =
                mad_run(backend.as_ref(), mixer, task, steps, eval_batches, 42).expect("mad_run");
            accs.push(acc);
        }
        let avg = accs.iter().sum::<f64>() / accs.len() as f64;
        let mut row = vec![mixer.to_string()];
        row.extend(accs.iter().map(|a| format!("{:.3}", a)));
        row.push(format!("{avg:.3}"));
        t.row(&row);
        out_rows.push(Json::obj(vec![
            ("mixer", Json::Str(mixer.to_string())),
            ("acc", Json::arr_f64(&accs)),
            ("avg", Json::Num(avg)),
        ]));
    }
    println!("{}", t.render());
    println!("paper Table 2 shape check: EFLA avg >= DeltaNet avg.");

    std::fs::create_dir_all("bench_results").ok();
    json::write_file(
        std::path::Path::new("bench_results/table2_mad.json"),
        &Json::obj(vec![
            ("steps", Json::Num(steps as f64)),
            ("tasks", Json::arr_str(&MadTask::all().map(|t| t.name().to_string()))),
            ("rows", Json::Arr(out_rows)),
        ]),
    )
    .unwrap();
    println!("json: bench_results/table2_mad.json");
}
