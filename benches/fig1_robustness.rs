//! Bench: Figure 1 — sMNIST robustness, EFLA vs DeltaNet.
//!
//! Trains the d=64 linear-attention classifier for both mixers at two
//! learning rates (1e-4, 3e-3 — the paper's bottom/top rows), then sweeps
//! the three corruption grids (dropout p, intensity scale, additive noise
//! sigma) on held-out data and prints accuracy-vs-interference series.
//!
//! Expected shape (paper Fig. 1): EFLA degrades slower than DeltaNet on all
//! three sweeps, most dramatically on intensity scaling, and the gap widens
//! at the larger learning rate.
//!
//! Env knobs: EFLA_F1_STEPS (default 60), EFLA_F1_EVAL (default 2 batches
//! of 32 per point).

use efla::coordinator::experiments::{robustness_run, RobustnessResult};
use efla::runtime::open_backend;
use efla::util::bench::Table;
use efla::util::json::{self, Json};

fn env_u64(name: &str, default: u64) -> u64 {
    std::env::var(name).ok().and_then(|v| v.parse().ok()).unwrap_or(default)
}

fn result_json(r: &RobustnessResult) -> Json {
    Json::obj(vec![
        ("mixer", Json::Str(r.mixer.clone())),
        ("lr", Json::Num(r.lr)),
        ("clean_acc", Json::Num(r.clean_acc)),
        (
            "sweeps",
            Json::Arr(
                r.sweeps
                    .iter()
                    .map(|(k, x, a)| {
                        Json::obj(vec![
                            ("sweep", Json::Str(k.clone())),
                            ("x", Json::Num(*x)),
                            ("acc", Json::Num(*a)),
                        ])
                    })
                    .collect(),
            ),
        ),
        (
            "train_curve",
            Json::Arr(
                r.train_curve
                    .iter()
                    .map(|&(s, l)| Json::arr_f64(&[s as f64, l as f64]))
                    .collect(),
            ),
        ),
    ])
}

fn main() {
    efla::util::logging::init();
    let steps = env_u64("EFLA_F1_STEPS", 24);
    let eval_batches = env_u64("EFLA_F1_EVAL", 2) as usize;
    let backend = open_backend(std::path::Path::new("artifacts")).expect("open backend");
    for m in ["efla", "deltanet"] {
        if !backend.has_family(&format!("clf_{m}")) {
            eprintln!("backend cannot build clf_{m}");
            std::process::exit(1);
        }
    }

    let lrs = [1e-4f64, 3e-3];
    let mut results = Vec::new();
    for &lr in &lrs {
        for mixer in ["deltanet", "efla"] {
            log::info!("training clf_{mixer} at lr={lr:.0e} for {steps} steps");
            let r = robustness_run(backend.as_ref(), mixer, lr, steps, eval_batches, 42)
                .expect("run");
            results.push(r);
        }
    }

    for &lr in &lrs {
        println!("\n## Figure 1 row (scaled): lr = {lr:.0e}, {steps} steps\n");
        let subset: Vec<&RobustnessResult> =
            results.iter().filter(|r| r.lr == lr).collect();
        for sweep in ["dropout", "scale", "noise"] {
            let xs: Vec<f64> = subset[0]
                .sweeps
                .iter()
                .filter(|(k, _, _)| k == sweep)
                .map(|(_, x, _)| *x)
                .collect();
            let mut t = Table::new(
                &std::iter::once("model".to_string())
                    .chain(xs.iter().map(|x| format!("{sweep}={x}")))
                    .map(|s| Box::leak(s.into_boxed_str()) as &str)
                    .collect::<Vec<&str>>(),
            );
            for r in &subset {
                let mut row = vec![r.mixer.clone()];
                for (_, _, acc) in r.sweeps.iter().filter(|(k, _, _)| k == sweep) {
                    row.push(format!("{acc:.3}"));
                }
                t.row(&row);
            }
            println!("{}", t.render());
        }
    }
    println!("paper Fig. 1 shape check: efla rows decay slower than deltanet, esp. scale.");

    std::fs::create_dir_all("bench_results").ok();
    json::write_file(
        std::path::Path::new("bench_results/fig1_robustness.json"),
        &Json::obj(vec![
            ("steps", Json::Num(steps as f64)),
            ("results", Json::Arr(results.iter().map(result_json).collect())),
        ]),
    )
    .unwrap();
    println!("json: bench_results/fig1_robustness.json");
}
