//! Bench: Table 1 — language modeling, DeltaNet vs EFLA (+ variants).
//!
//! Trains all four token-mixer variants of the `small` preset on the same
//! synthetic corpus with the same budget and prints the Table-1 row set:
//! held-out perplexity (Wiki./LMB. stand-in) and downstream probe accuracies
//! (LAMBADA/PIQA/BoolQ stand-ins; see DESIGN.md §5 for the substitutions).
//!
//! Expected shape (paper Table 1): EFLA ppl <= DeltaNet ppl at equal budget;
//! EFLA avg probe accuracy >= DeltaNet.
//!
//! Env knobs (single-core CPU defaults are deliberately small):
//!   EFLA_T1_STEPS   training steps per variant   (default 30)
//!   EFLA_T1_PRESET  artifact preset              (default "mini")
//!   EFLA_T1_EVAL    eval batches                 (default 4)
//!   EFLA_T1_LR      peak learning rate           (default 1e-3; paper
//!                   Appendix C: EFLA needs a larger lr than DeltaNet's
//!                   3e-4 default — both get the same budget here)

use efla::coordinator::experiments::lm_run;
use efla::runtime::open_backend;
use efla::util::bench::Table;
use efla::util::json::{self, Json};

fn env_u64(name: &str, default: u64) -> u64 {
    std::env::var(name).ok().and_then(|v| v.parse().ok()).unwrap_or(default)
}

fn main() {
    efla::util::logging::init();
    let steps = env_u64("EFLA_T1_STEPS", 30);
    let preset = std::env::var("EFLA_T1_PRESET").unwrap_or_else(|_| "mini".into());
    let eval_batches = env_u64("EFLA_T1_EVAL", 4) as usize;
    let peak_lr: f64 = std::env::var("EFLA_T1_LR")
        .ok()
        .and_then(|v| v.parse().ok())
        .unwrap_or(1e-3);
    let backend = open_backend(std::path::Path::new("artifacts")).expect("open backend");

    let mixers: Vec<&str> = ["deltanet", "efla", "efla_adaptive", "efla_loose"]
        .into_iter()
        .filter(|m| backend.has_family(&format!("lm_{preset}_{m}")))
        .collect();
    if mixers.is_empty() {
        eprintln!("backend cannot build any lm_{preset}_* family (unknown preset?)");
        std::process::exit(1);
    }

    println!(
        "## Table 1 (scaled): preset={preset}, {steps} steps, peak_lr={peak_lr}, shared corpus\n"
    );
    let mut rows = Vec::new();
    let mut t = Table::new(&[
        "model",
        "train loss",
        "ppl (down)",
        "final_word",
        "multi_choice",
        "bool_query",
        "avg acc (up)",
        "secs",
    ]);
    for mixer in &mixers {
        let row = lm_run(backend.as_ref(), &preset, mixer, steps, eval_batches, 42, peak_lr)
            .expect("lm_run");
        let acc: Vec<f64> = row.probe_acc.iter().map(|(_, a)| *a).collect();
        let avg = acc.iter().sum::<f64>() / acc.len().max(1) as f64;
        t.row(&[
            mixer.to_string(),
            format!("{:.4}", row.train_loss),
            format!("{:.2}", row.ppl),
            format!("{:.3}", acc.first().copied().unwrap_or(f64::NAN)),
            format!("{:.3}", acc.get(1).copied().unwrap_or(f64::NAN)),
            format!("{:.3}", acc.get(2).copied().unwrap_or(f64::NAN)),
            format!("{:.3}", avg),
            format!("{:.0}", row.wall_secs),
        ]);
        rows.push(Json::obj(vec![
            ("mixer", Json::Str(mixer.to_string())),
            ("train_loss", Json::Num(row.train_loss as f64)),
            ("ppl", Json::Num(row.ppl)),
            ("avg_acc", Json::Num(avg)),
            (
                "probes",
                Json::Arr(
                    row.probe_acc
                        .iter()
                        .map(|(n, a)| {
                            Json::obj(vec![
                                ("name", Json::Str(n.clone())),
                                ("acc", Json::Num(*a)),
                            ])
                        })
                        .collect(),
                ),
            ),
        ]));
    }
    println!("{}", t.render());
    println!("paper Table 1 shape check: EFLA row should beat DeltaNet on ppl and avg acc.");

    std::fs::create_dir_all("bench_results").ok();
    json::write_file(
        std::path::Path::new("bench_results/table1_lm.json"),
        &Json::obj(vec![
            ("preset", Json::Str(preset)),
            ("steps", Json::Num(steps as f64)),
            ("rows", Json::Arr(rows)),
        ]),
    )
    .unwrap();
    println!("json: bench_results/table1_lm.json");
}
