//! Bench: Figure 2 — EFLA robustness vs learning rate (Appendix C).
//!
//! The saturation story: EFLA's exact gate alpha = (1-e^{-beta*lambda})/lambda
//! is sub-linear in input energy, so EFLA needs a LARGER learning rate to
//! stay responsive; with a conservative lr it underfits and loses
//! robustness. Trains EFLA classifiers at lr in {1e-4, 1e-3, 3e-3} and
//! sweeps the same three corruption grids as Fig. 1.
//!
//! Expected shape (paper Fig. 2): accuracy under interference increases
//! with lr across the grid.
//!
//! Env knobs: EFLA_F2_STEPS (default 60), EFLA_F2_EVAL (default 2).

use efla::coordinator::experiments::robustness_run;
use efla::runtime::open_backend;
use efla::util::bench::Table;
use efla::util::json::{self, Json};

fn env_u64(name: &str, default: u64) -> u64 {
    std::env::var(name).ok().and_then(|v| v.parse().ok()).unwrap_or(default)
}

fn main() {
    efla::util::logging::init();
    let steps = env_u64("EFLA_F2_STEPS", 24);
    let eval_batches = env_u64("EFLA_F2_EVAL", 2) as usize;
    let backend = open_backend(std::path::Path::new("artifacts")).expect("open backend");
    if !backend.has_family("clf_efla") {
        eprintln!("backend cannot build clf_efla");
        std::process::exit(1);
    }

    let lrs = [1e-4f64, 1e-3, 3e-3];
    let mut results = Vec::new();
    for &lr in &lrs {
        log::info!("training clf_efla at lr={lr:.0e} for {steps} steps");
        results.push(
            robustness_run(backend.as_ref(), "efla", lr, steps, eval_batches, 42).expect("run"),
        );
    }

    println!("\n## Figure 2 (scaled): EFLA, lr sweep, {steps} steps\n");
    for sweep in ["scale", "noise", "dropout"] {
        let xs: Vec<f64> = results[0]
            .sweeps
            .iter()
            .filter(|(k, _, _)| k == sweep)
            .map(|(_, x, _)| *x)
            .collect();
        let headers: Vec<&str> = std::iter::once("lr".to_string())
            .chain(xs.iter().map(|x| format!("{sweep}={x}")))
            .map(|s| Box::leak(s.into_boxed_str()) as &str)
            .collect();
        let mut t = Table::new(&headers);
        for r in &results {
            let mut row = vec![format!("{:.0e}", r.lr)];
            for (_, _, acc) in r.sweeps.iter().filter(|(k, _, _)| k == sweep) {
                row.push(format!("{acc:.3}"));
            }
            t.row(&row);
        }
        println!("{}", t.render());
    }
    println!("paper Fig. 2 shape check: robustness improves with larger lr (saturation effect).");

    std::fs::create_dir_all("bench_results").ok();
    json::write_file(
        std::path::Path::new("bench_results/fig2_lr_scaling.json"),
        &Json::obj(vec![
            ("steps", Json::Num(steps as f64)),
            (
                "results",
                Json::Arr(
                    results
                        .iter()
                        .map(|r| {
                            Json::obj(vec![
                                ("lr", Json::Num(r.lr)),
                                ("clean_acc", Json::Num(r.clean_acc)),
                                (
                                    "sweeps",
                                    Json::Arr(
                                        r.sweeps
                                            .iter()
                                            .map(|(k, x, a)| {
                                                Json::obj(vec![
                                                    ("sweep", Json::Str(k.clone())),
                                                    ("x", Json::Num(*x)),
                                                    ("acc", Json::Num(*a)),
                                                ])
                                            })
                                            .collect(),
                                    ),
                                ),
                            ])
                        })
                        .collect(),
                ),
            ),
        ]),
    )
    .unwrap();
    println!("json: bench_results/fig2_lr_scaling.json");
}
