//! Bench: kernel-level analysis (paper §3 + §6).
//!
//! Regenerates, on the pure-Rust recurrence substrate:
//!   0. GEMM GFLOP/s — scalar tier vs the dispatched AVX2+FMA microkernel
//!      at 64/256/512 cubes (the perf-trajectory anchor; writes the
//!      root-level BENCH_kernel_gemm.json);
//!   1. the integrator error sweep — |out - exact| vs stiffness beta*lambda
//!      for Euler / RK-2 / RK-4 / EFLA (the paper's core numerical claim);
//!   2. transition-eigenvalue table (spectral gate, paper Eq. 33);
//!   3. sequential vs chunkwise throughput across chunk sizes (the
//!      hardware-efficiency argument for the chunkwise form);
//!   4. chunkwise consistency errors (parallel form == sequential form);
//!   5. the exact gate's cost relative to Euler's (EFLA's only overhead);
//!   6. model forward thread scaling (writes the root-level
//!      BENCH_forward_threads.json);
//!   7. serving prompt ingestion — chunked parallel prefill vs
//!      token-at-a-time decode, session- and server-level (writes the
//!      root-level BENCH_serving.json);
//!   8. serving continuous batching — staggered arrivals through the
//!      engine loop vs sequential one-request-at-a-time: aggregate
//!      tok/s, e2e/queue-wait percentiles, plus the replica router
//!      (1 vs 3 in-process replicas behind `efla route`, bit-identical
//!      outputs; writes the root-level BENCH_serving_cb.json);
//!   9. serving slot-batched decode — all busy slots' rows through one
//!      class-pinned packed GEMM vs the retired per-slot single-row
//!      formulation at 1/4/16/32 busy slots (writes the root-level
//!      BENCH_serving_batched.json);
//!  10. serving session state cache — turn-2 TTFT of a cached resume
//!      (prefill only the new tokens) vs a cold full-transcript replay
//!      at conversation depths 256/1024/4096, bit-identical outputs
//!      (writes the root-level BENCH_serving_state_cache.json);
//!  11. serving session affinity — turn-2 TTFT landing on the replica
//!      that parked the state (affine) vs a session-blind replica (cold
//!      replay) vs failover with state migration through the
//!      `/v1/state/{session}` wire form, bit-identical outputs (writes
//!      the root-level BENCH_serving_affinity.json).
//!
//! Env knobs: EFLA_BENCH_FAST=1 shrinks everything (CI smoke);
//! EFLA_FORCE_SCALAR=1 pins the matmul dispatcher to the scalar tier.

use std::sync::atomic::{AtomicBool, AtomicU64, Ordering};
use std::sync::{mpsc, Mutex};
use std::time::{Duration, Instant};

use efla::attention::{alpha_efla, chunkwise_delta, gates, sequential_delta, Gate};
use efla::coordinator::experiments::{chunkwise_consistency, integrator_error};
use efla::coordinator::server::{GenRequest, Server, ServerConfig};
use efla::coordinator::session::Session;
use efla::runtime::cpu::config::family_config;
use efla::runtime::cpu::exec::Executor;
use efla::runtime::cpu::model::lm_loss;
use efla::runtime::cpu::ops;
use efla::runtime::cpu::params::ParamSet;
use efla::runtime::CpuBackend;
use efla::serve::engine::{run_engine, EngineShared, Event, Submission};
use efla::serve::http;
use efla::serve::router::{Router, RouterConfig};
use efla::serve::state_cache::CachedState;
use efla::serve::Frontend;
use efla::tensor::{gemm, matmul_into, Tensor};
use efla::util::bench::{bench, fmt_secs, Stats, Table};
use efla::util::json::{self, Json};
use efla::util::rng::Rng;

fn fast() -> bool {
    std::env::var("EFLA_BENCH_FAST").is_ok()
}

fn main() {
    let (l, d) = if fast() { (128, 16) } else { (512, 32) };
    let mut report = Vec::new();

    // ---- 0. GEMM GFLOP/s: scalar tier vs dispatched SIMD ------------
    let kernel = gemm::active_kernel();
    println!("## GEMM single-thread GFLOP/s (dispatched kernel: {kernel:?})\n");
    let gemm_iters = if fast() { 2 } else { 6 };
    let mut t = Table::new(&["size", "scalar GFLOP/s", "dispatched GFLOP/s", "speedup"]);
    let mut gemm_points = Vec::new();
    for &s in &[64usize, 256, 512] {
        let mut rng = Rng::new(s as u64);
        let a = rng.normal_vec(s * s, 0.0, 0.1);
        let b = rng.normal_vec(s * s, 0.0, 0.1);
        let mut out = vec![0.0f32; s * s];
        let flops = 2.0 * (s as f64).powi(3);
        let st_scalar = bench(1, gemm_iters, || {
            out.iter_mut().for_each(|x| *x = 0.0);
            gemm::scalar::matmul_into(&a, &b, &mut out, s, s, s);
            std::hint::black_box(&out);
        });
        let st_simd = bench(1, gemm_iters, || {
            out.iter_mut().for_each(|x| *x = 0.0);
            matmul_into(&a, &b, &mut out, s, s, s);
            std::hint::black_box(&out);
        });
        let g_scalar = flops / st_scalar.mean.max(1e-12) / 1e9;
        let g_simd = flops / st_simd.mean.max(1e-12) / 1e9;
        let speedup = st_scalar.mean / st_simd.mean.max(1e-12);
        t.row(&[
            format!("{s}x{s}x{s}"),
            format!("{g_scalar:.2}"),
            format!("{g_simd:.2}"),
            format!("{speedup:.2}x"),
        ]);
        gemm_points.push(Json::obj(vec![
            ("size", Json::Num(s as f64)),
            ("scalar_gflops", Json::Num(g_scalar)),
            ("dispatched_gflops", Json::Num(g_simd)),
            ("speedup", Json::Num(speedup)),
        ]));
    }
    println!("{}", t.render());
    let gemm_json = Json::obj(vec![
        ("bench", Json::Str("gemm_gflops".into())),
        ("kernel", Json::Str(format!("{kernel:?}"))),
        ("points", Json::Arr(gemm_points)),
    ]);
    // Machine-readable one-liner + root-level trajectory file. Fast mode
    // (CI smoke) must not overwrite the committed trajectory with
    // throwaway low-iteration numbers.
    println!("BENCH {}", gemm_json.to_string());
    if !fast() {
        json::write_file(std::path::Path::new("BENCH_kernel_gemm.json"), &gemm_json).unwrap();
    }
    report.push(("gemm_gflops", gemm_json));

    // ---- 1. error vs stiffness ------------------------------------
    println!("## Integrator error vs stiffness (L={l}, d={d}, max |out - exact|)\n");
    let stiffness = [0.1, 0.25, 0.5, 1.0, 1.5, 2.0, 3.0];
    let gates_list = [Gate::Euler, Gate::Rk(2), Gate::Rk(4), Gate::Efla];
    let mut t = Table::new(&["beta*lambda", "euler(deltanet)", "rk2", "rk4", "efla(exact)"]);
    for &x in &stiffness {
        let mut row = vec![format!("{x:.2}")];
        for g in gates_list {
            let e = integrator_error(g, x, l, d, 42);
            row.push(if e == 0.0 { "0 (exact)".into() } else { format!("{e:.3e}") });
        }
        t.row(&row);
    }
    println!("{}", t.render());
    report.push(("error_vs_stiffness", t.to_json()));

    // ---- 2. spectral gate table ------------------------------------
    println!("## Transition eigenvalue along k (1 - alpha*lambda), beta = 0.9\n");
    let mut t = Table::new(&["lambda", "euler", "rk2", "efla", "exp(-beta*lambda)"]);
    for lam in [0.1f32, 0.5, 1.0, 2.0, 4.0, 8.0] {
        let beta = 0.9f32;
        t.row(&[
            format!("{lam}"),
            format!("{:+.4}", gates::transition_eigenvalue(Gate::Euler, beta, lam)),
            format!("{:+.4}", gates::transition_eigenvalue(Gate::Rk(2), beta, lam)),
            format!("{:+.4}", gates::transition_eigenvalue(Gate::Efla, beta, lam)),
            format!("{:+.4}", (-beta * lam).exp()),
        ]);
    }
    println!("{}", t.render());
    println!("(euler leaves (-1,1) at beta*lambda > 2 — the instability EFLA removes)\n");
    report.push(("spectral_gate", t.to_json()));

    // ---- 3. throughput: sequential vs chunkwise --------------------
    println!("## Rust reference throughput (tokens/sec, single head, L={l} d={d})\n");
    let mut rng = Rng::new(7);
    let q = Tensor::from_vec(&[l, d], rng.normal_vec(l * d, 0.0, 1.0));
    let k = Tensor::from_vec(&[l, d], rng.normal_vec(l * d, 0.0, 0.7));
    let v = Tensor::from_vec(&[l, d], rng.normal_vec(l * d, 0.0, 1.0));
    let beta: Vec<f32> = (0..l).map(|_| rng.f32()).collect();
    let iters = if fast() { 3 } else { 10 };

    let mut t = Table::new(&["impl", "mean", "p95", "tokens/s"]);
    let s = bench(1, iters, || {
        std::hint::black_box(sequential_delta(Gate::Efla, &q, &k, &v, &beta));
    });
    t.row(&[
        "sequential".into(),
        fmt_secs(s.mean),
        fmt_secs(s.p95),
        format!("{:.0}", s.per_sec(l as f64)),
    ]);
    for chunk in [16usize, 32, 64, 128] {
        let s = bench(1, iters, || {
            std::hint::black_box(chunkwise_delta(Gate::Efla, &q, &k, &v, &beta, chunk));
        });
        t.row(&[
            format!("chunkwise C={chunk}"),
            fmt_secs(s.mean),
            fmt_secs(s.p95),
            format!("{:.0}", s.per_sec(l as f64)),
        ]);
    }
    println!("{}", t.render());
    report.push(("throughput", t.to_json()));

    // ---- 4. chunkwise consistency ----------------------------------
    println!("## Chunkwise == sequential (max abs diff, all gates)\n");
    let mut t = Table::new(&["gate", "C=16", "C=64"]);
    for g in gates_list {
        t.row(&[
            g.name(),
            format!("{:.2e}", chunkwise_consistency(g, 96, 16, 16, 3)),
            format!("{:.2e}", chunkwise_consistency(g, 96, 16, 64, 3)),
        ]);
    }
    println!("{}", t.render());
    report.push(("consistency", t.to_json()));

    // ---- 5. alpha gate cost (the only EFLA overhead vs DeltaNet) ---
    println!("## Gate computation cost (per 1e6 tokens)\n");
    let xs: Vec<f32> = (0..1_000_000).map(|i| (i % 97) as f32 * 0.05).collect();
    let mut sink = 0f32;
    let s_euler = bench(1, 3, || {
        sink += xs.iter().map(|&x| gates::alpha_euler(x)).sum::<f32>();
    });
    let s_efla = bench(1, 3, || {
        sink += xs.iter().map(|&x| alpha_efla(0.9, x)).sum::<f32>();
    });
    std::hint::black_box(sink);
    let mut t = Table::new(&["gate", "per 1M tokens", "overhead"]);
    t.row(&["euler".into(), fmt_secs(s_euler.mean), "1.0x".into()]);
    t.row(&[
        "efla".into(),
        fmt_secs(s_efla.mean),
        format!("{:.1}x", s_efla.mean / s_euler.mean.max(1e-12)),
    ]);
    println!("{}", t.render());
    println!("(the exact gate is one expm1 per token — negligible next to the d^2 state update)\n");
    report.push(("gate_cost", t.to_json()));

    // ---- 6. model forward thread scaling ---------------------------
    // Full LM forward through the layered CPU model at 1/2/4/max worker
    // threads: the (batch x head) chunkwise kernels and the projection
    // matmuls fan out over the executor, numerics bit-identical.
    let family = if fast() { "lm_tiny_efla" } else { "lm_mini_efla" };
    let cfg = family_config(family).unwrap();
    let params = ParamSet::init(&cfg, 42);
    let rows = cfg.batch * cfg.seq;
    let mut rng = Rng::new(11);
    let toks: Vec<i32> = (0..rows).map(|_| rng.below(cfg.vocab as u64) as i32).collect();
    let tgts: Vec<i32> = (0..rows).map(|_| rng.below(cfg.vocab as u64) as i32).collect();
    let max_threads = std::thread::available_parallelism().map(|n| n.get()).unwrap_or(1);
    let mut counts = vec![1usize, 2, 4];
    if !counts.contains(&max_threads) {
        counts.push(max_threads);
    }
    counts.sort_unstable();
    counts.dedup();

    println!(
        "## Model forward thread scaling ({family}: B={} L={} layers={} heads={}, max={max_threads})\n",
        cfg.batch, cfg.seq, cfg.n_layers, cfg.n_heads
    );
    let iters = if fast() { 3 } else { 8 };
    let mut t = Table::new(&["threads", "mean", "p95", "tokens/s", "speedup"]);
    let mut base_mean = 0.0f64;
    let mut scaling = Vec::new();
    for &threads in &counts {
        let exec = Executor::new(threads);
        let s = bench(1, iters, || {
            std::hint::black_box(
                lm_loss(&cfg, &params, &exec, &toks, &tgts, cfg.batch, cfg.seq, None)
                    .unwrap(),
            );
        });
        if threads == 1 {
            base_mean = s.mean;
        }
        let speedup = base_mean / s.mean.max(1e-12);
        t.row(&[
            format!("{threads}"),
            fmt_secs(s.mean),
            fmt_secs(s.p95),
            format!("{:.0}", s.per_sec(rows as f64)),
            format!("{speedup:.2}x"),
        ]);
        scaling.push(Json::obj(vec![
            ("threads", Json::Num(threads as f64)),
            ("mean_secs", Json::Num(s.mean)),
            ("tokens_per_sec", Json::Num(s.per_sec(rows as f64))),
            ("speedup_vs_1", Json::Num(speedup)),
        ]));
    }
    println!("{}", t.render());
    let scaling_json = Json::obj(vec![
        ("bench", Json::Str("forward_thread_scaling".into())),
        ("kernel", Json::Str(format!("{:?}", gemm::active_kernel()))),
        ("family", Json::Str(family.into())),
        ("rows", Json::Num(rows as f64)),
        ("max_parallelism", Json::Num(max_threads as f64)),
        ("points", Json::Arr(scaling)),
    ]);
    // Machine-readable one-liner + root-level trajectory file (committed
    // across PRs so the perf trajectory is tracked; fast mode must not
    // overwrite it with throwaway numbers).
    println!("BENCH {}", scaling_json.to_string());
    if !fast() {
        json::write_file(std::path::Path::new("BENCH_forward_threads.json"), &scaling_json)
            .unwrap();
    }
    report.push(("forward_thread_scaling", scaling_json));

    // ---- 7. serving: chunked prefill vs token-at-a-time ------------
    // Prompt-ingestion throughput of the serving engine. Session level:
    // one slot's prompt through `prefill` in chunks vs one token per
    // batched `decode` step (the pre-prefill serving behavior, which pays
    // a full decode batch per prompt token). Server level: end-to-end
    // tokens/s + mean TTFT of the two scheduler modes on the same request
    // mix. The two paths produce bit-identical logits and state (pinned
    // by tests/serving_prefill.rs) — this section measures the speed gap.
    let backend = CpuBackend::new();
    let session = Session::init(&backend, "lm_tiny_efla", 42).expect("open serving session");
    let serve_iters = if fast() { 2 } else { 5 };
    let plens: &[usize] = if fast() { &[64, 128] } else { &[64, 256, 1024] };
    let prefill_chunk = 64usize;
    println!(
        "## Serving prompt ingestion (lm_tiny_efla, prefill_chunk={prefill_chunk}, \
         threads={})\n",
        session.threads()
    );
    let vocab = session.vocab().unwrap();
    let decode_b = session.decode_batch().unwrap();
    let mut t = Table::new(&["prompt len", "prefill tok/s", "token-at-a-time tok/s", "speedup"]);
    let mut serve_points = Vec::new();
    for &plen in plens {
        let mut rng = Rng::new(plen as u64);
        let toks: Vec<i32> = (0..plen).map(|_| rng.below(vocab as u64) as i32).collect();
        let st_prefill = bench(1, serve_iters, || {
            let mut state = session.decode_state().unwrap();
            let mut pos = 0;
            while pos < plen {
                let end = (pos + prefill_chunk).min(plen);
                std::hint::black_box(session.prefill(&mut state, 0, &toks[pos..end]).unwrap());
                pos = end;
            }
        });
        let st_decode = bench(1, serve_iters, || {
            let mut state = session.decode_state().unwrap();
            let mut step = vec![0i32; decode_b];
            for &tk in &toks {
                step[0] = tk;
                std::hint::black_box(session.decode(&mut state, &step).unwrap());
            }
        });
        let tps_prefill = st_prefill.per_sec(plen as f64);
        let tps_decode = st_decode.per_sec(plen as f64);
        let speedup = st_decode.mean / st_prefill.mean.max(1e-12);
        t.row(&[
            format!("{plen}"),
            format!("{tps_prefill:.0}"),
            format!("{tps_decode:.0}"),
            format!("{speedup:.2}x"),
        ]);
        serve_points.push(Json::obj(vec![
            ("prompt_len", Json::Num(plen as f64)),
            ("prefill_tokens_per_sec", Json::Num(tps_prefill)),
            ("token_at_a_time_tokens_per_sec", Json::Num(tps_decode)),
            ("speedup", Json::Num(speedup)),
        ]));
    }
    println!("{}", t.render());

    // End-to-end engine comparison on one mixed request batch.
    let run_server = |chunk: usize| {
        let cfg = ServerConfig {
            prefill_chunk: chunk,
            prefill_token_budget: 256,
            ..ServerConfig::default()
        };
        let mut server = Server::with_config(&session, 7, cfg).unwrap();
        let mut rng = Rng::new(9);
        let n_req = if fast() { 6u64 } else { 12 };
        let plen = if fast() { 96 } else { 192 };
        for id in 0..n_req {
            let prompt: Vec<i32> =
                (0..plen).map(|_| rng.below(vocab as u64) as i32).collect();
            let req = GenRequest {
                id,
                prompt,
                max_new: 8,
                temperature: 0.0,
                deadline: None,
                session_id: None,
            };
            server.submit(req).unwrap();
        }
        server.run_to_completion().unwrap();
        (
            server.stats.tokens_per_sec(),
            server.stats.mean_ttft_secs(),
            server.stats.engine_steps,
        )
    };
    let (tps_chunked, ttft_chunked, steps_chunked) = run_server(prefill_chunk);
    let (tps_legacy, ttft_legacy, steps_legacy) = run_server(0);
    let mut t = Table::new(&["engine mode", "tok/s", "mean TTFT", "engine steps"]);
    t.row(&[
        format!("chunked prefill C={prefill_chunk}"),
        format!("{tps_chunked:.0}"),
        fmt_secs(ttft_chunked),
        format!("{steps_chunked}"),
    ]);
    t.row(&[
        "token-at-a-time".into(),
        format!("{tps_legacy:.0}"),
        fmt_secs(ttft_legacy),
        format!("{steps_legacy}"),
    ]);
    println!("{}", t.render());
    let serving_json = Json::obj(vec![
        ("bench", Json::Str("serving_prefill".into())),
        ("kernel", Json::Str(format!("{:?}", gemm::active_kernel()))),
        ("family", Json::Str("lm_tiny_efla".into())),
        ("threads", Json::Num(session.threads() as f64)),
        ("prefill_chunk", Json::Num(prefill_chunk as f64)),
        ("points", Json::Arr(serve_points)),
        (
            "server",
            Json::obj(vec![
                ("chunked_tokens_per_sec", Json::Num(tps_chunked)),
                ("chunked_mean_ttft_secs", Json::Num(ttft_chunked)),
                ("legacy_tokens_per_sec", Json::Num(tps_legacy)),
                ("legacy_mean_ttft_secs", Json::Num(ttft_legacy)),
            ]),
        ),
    ]);
    println!("BENCH {}", serving_json.to_string());
    if !fast() {
        json::write_file(std::path::Path::new("BENCH_serving.json"), &serving_json).unwrap();
    }
    report.push(("serving_prefill", serving_json));

    // ---- 8. serving: continuous batching vs sequential -------------
    // The decode graph computes every row of the fixed batch whether one
    // or all slots are occupied, so serving requests one at a time wastes
    // (batch - 1)/batch of every step. Continuous batching fills the
    // slots from a staggered arrival stream and should win on aggregate
    // tokens/s by roughly the slot count; CI's bench gate enforces the
    // direction (scripts/bench_gate.py, section `serving_cb`).
    let cb_req = if fast() { 8u64 } else { 16 };
    let cb_plen = if fast() { 48usize } else { 96 };
    let cb_max_new = if fast() { 8usize } else { 16 };
    let stagger = Duration::from_millis(2);
    println!(
        "## Serving continuous batching ({cb_req} requests, prompt {cb_plen}, \
         max_new {cb_max_new})\n"
    );
    let mk_prompt = |id: u64| -> Vec<i32> {
        let mut rng = Rng::new(0xCB ^ id);
        (0..cb_plen).map(|_| rng.below(vocab as u64) as i32).collect()
    };

    // Sequential baseline: each request occupies the engine alone.
    let t0 = Instant::now();
    let mut seq_tokens = 0u64;
    for id in 0..cb_req {
        let mut server = Server::with_config(&session, 7, ServerConfig::default()).unwrap();
        let prompt = mk_prompt(id);
        let req = GenRequest {
            id,
            prompt,
            max_new: cb_max_new,
            temperature: 0.0,
            deadline: None,
            session_id: None,
        };
        server.submit(req).unwrap();
        server.run_to_completion().unwrap();
        seq_tokens += server.stats.tokens_processed;
    }
    let seq_wall = t0.elapsed().as_secs_f64();
    let seq_tps = seq_tokens as f64 / seq_wall.max(1e-9);

    // Continuous batching: staggered arrivals through the engine loop.
    let shared = EngineShared::new(1024);
    let stop = AtomicBool::new(false);
    let (cb_tx, cb_rx) = mpsc::sync_channel::<Submission>(64);
    let t0 = Instant::now();
    let (cb_stats, cb_results) = std::thread::scope(|s| {
        let stop = &stop;
        let submitter = s.spawn(move || {
            let mut rxs = Vec::new();
            for id in 0..cb_req {
                let (ev_tx, ev_rx) = mpsc::channel();
                let prompt = mk_prompt(id);
                let req = GenRequest {
                    id,
                    prompt,
                    max_new: cb_max_new,
                    temperature: 0.0,
                    deadline: None,
                    session_id: None,
                };
                let sub =
                    Submission { req, submitted: Instant::now(), stream: false, events: ev_tx };
                cb_tx.send(sub).unwrap();
                rxs.push(ev_rx);
                std::thread::sleep(stagger);
            }
            let mut out = Vec::new();
            for ev_rx in rxs {
                loop {
                    match ev_rx.recv().unwrap() {
                        Event::Done(r) => {
                            out.push(r);
                            break;
                        }
                        Event::Token(_) => {}
                        Event::Rejected(e) => panic!("bench request rejected: {e}"),
                    }
                }
            }
            stop.store(true, Ordering::SeqCst);
            out
        });
        let stats =
            run_engine(&session, ServerConfig::default(), 7, cb_rx, &shared, stop).unwrap();
        (stats, submitter.join().expect("submitter thread"))
    });
    let cb_wall = t0.elapsed().as_secs_f64();
    let cb_tps = cb_stats.tokens_processed as f64 / cb_wall.max(1e-9);
    let cb_speedup = cb_tps / seq_tps.max(1e-9);
    let e2e_stats = Stats::from_samples(cb_results.iter().map(|r| r.e2e_secs).collect());
    let qw_stats = Stats::from_samples(cb_results.iter().map(|r| r.queue_wait_secs).collect());

    let mut t = Table::new(&["mode", "tok/s", "p50 e2e", "p95 e2e", "p95 queue wait"]);
    t.row(&[
        "continuous batching".into(),
        format!("{cb_tps:.0}"),
        fmt_secs(e2e_stats.p50),
        fmt_secs(e2e_stats.p95),
        fmt_secs(qw_stats.p95),
    ]);
    t.row(&[
        "sequential (1 req at a time)".into(),
        format!("{seq_tps:.0}"),
        "-".into(),
        "-".into(),
        "-".into(),
    ]);
    println!("{}", t.render());
    println!("(continuous batching speedup: {cb_speedup:.2}x on aggregate tokens/s)\n");

    // ---- 8b. serving: router over 1 vs 3 in-process replicas -------
    // The routing claim on top of continuous batching: a replica holds
    // no KV cache, so adding one adds its full decode capacity. Route
    // the same concurrent load through `efla route`-style topologies of
    // 1 and 3 identically seeded single-thread replicas; the bench gate
    // (scripts/bench_gate.py, `serving_cb.router`) enforces that the
    // 3-replica aggregate beats 1 replica, and the greedy outputs are
    // asserted bit-identical between the two topologies.
    let rt_requests = if fast() { 9u64 } else { 18 };
    let rt_plen = if fast() { 24usize } else { 48 };
    let rt_max_new = if fast() { 6usize } else { 12 };
    let rt_clients = 6usize;
    println!(
        "## Serving router ({rt_requests} requests, {rt_clients} clients, \
         1 vs 3 single-thread replicas)\n"
    );
    let run_router = |n_replicas: usize| -> (f64, Vec<(u64, Vec<i64>)>) {
        let mut frontends = Vec::new();
        let mut addrs = Vec::new();
        let mut rep_flags = Vec::new();
        for _ in 0..n_replicas {
            let fe = Frontend::bind("127.0.0.1:0").unwrap();
            addrs.push(fe.local_addr().unwrap().to_string());
            rep_flags.push(fe.shutdown_flag());
            frontends.push(fe);
        }
        let rcfg = RouterConfig { health_interval_ms: 50, seed: 7, ..RouterConfig::default() };
        let router = Router::bind("127.0.0.1:0", addrs, rcfg).unwrap();
        let raddr = router.local_addr().unwrap().to_string();
        let router_flag = router.shutdown_flag();
        std::thread::scope(|s| {
            for fe in frontends {
                s.spawn(move || {
                    let backend = CpuBackend::with_threads(1);
                    let session = Session::init(&backend, "lm_tiny_efla", 42).unwrap();
                    fe.run(&session, ServerConfig::default(), 7).unwrap();
                });
            }
            s.spawn(move || router.run().unwrap());
            // Readiness: every replica must have answered a health probe.
            loop {
                if let Ok(resp) = http::request(&raddr, "GET", "/stats", b"") {
                    let j = json::parse(&resp.text()).unwrap();
                    let reps = j.get("replicas").as_arr().unwrap_or(&[]);
                    let live = reps
                        .iter()
                        .filter(|r| r.get("probes_ok").as_f64().unwrap_or(0.0) >= 1.0)
                        .count();
                    if live == n_replicas {
                        break;
                    }
                }
                std::thread::sleep(Duration::from_millis(20));
            }
            let generate = |id: u64| -> Vec<i64> {
                let mut rng = Rng::new(0xD00 ^ id);
                let toks: Vec<String> =
                    (0..rt_plen).map(|_| rng.below(vocab as u64).to_string()).collect();
                let body = format!(
                    "{{\"id\": {id}, \"tokens\": [{}], \"max_tokens\": {rt_max_new}}}",
                    toks.join(",")
                );
                loop {
                    match http::request(&raddr, "POST", "/v1/generate", body.as_bytes()) {
                        Ok(resp) if resp.status == 200 => {
                            let j = json::parse(&resp.text()).unwrap();
                            let arr = j.get("tokens").as_arr().expect("tokens array");
                            return arr.iter().map(|t| t.as_i64().unwrap()).collect();
                        }
                        // Saturated or still warming up: back off and retry.
                        Ok(resp) if resp.status == 429 || resp.status == 503 => {
                            std::thread::sleep(Duration::from_millis(20));
                        }
                        Ok(resp) => panic!("router answered {}: {}", resp.status, resp.text()),
                        Err(_) => std::thread::sleep(Duration::from_millis(20)),
                    }
                }
            };
            let t0 = Instant::now();
            let next = AtomicU64::new(0);
            let outs: Mutex<Vec<(u64, Vec<i64>)>> = Mutex::new(Vec::new());
            std::thread::scope(|cs| {
                for _ in 0..rt_clients {
                    cs.spawn(|| loop {
                        let id = next.fetch_add(1, Ordering::SeqCst);
                        if id >= rt_requests {
                            break;
                        }
                        let toks = generate(id);
                        outs.lock().unwrap().push((id, toks));
                    });
                }
            });
            let wall = t0.elapsed().as_secs_f64();
            router_flag.store(true, Ordering::SeqCst);
            for f in &rep_flags {
                f.store(true, Ordering::SeqCst);
            }
            let mut outs = outs.into_inner().unwrap();
            outs.sort();
            let total: usize = outs.iter().map(|(_, toks)| toks.len()).sum();
            (total as f64 / wall.max(1e-9), outs)
        })
    };
    let (rt_tps_1, rt_out_1) = run_router(1);
    let (rt_tps_3, rt_out_3) = run_router(3);
    assert_eq!(
        rt_out_1, rt_out_3,
        "greedy outputs must be bit-identical through 1- and 3-replica topologies"
    );
    let mut t = Table::new(&["topology", "aggregate tok/s", "speedup"]);
    t.row(&["router + 1 replica".into(), format!("{rt_tps_1:.0}"), "1.00x".into()]);
    t.row(&[
        "router + 3 replicas".into(),
        format!("{rt_tps_3:.0}"),
        format!("{:.2}x", rt_tps_3 / rt_tps_1.max(1e-9)),
    ]);
    println!("{}", t.render());
    println!("(outputs bit-identical across topologies; single-thread replicas)\n");

    let cb_json = Json::obj(vec![
        ("bench", Json::Str("serving_cb".into())),
        ("kernel", Json::Str(format!("{:?}", gemm::active_kernel()))),
        ("family", Json::Str("lm_tiny_efla".into())),
        ("threads", Json::Num(session.threads() as f64)),
        ("requests", Json::Num(cb_req as f64)),
        ("prompt_len", Json::Num(cb_plen as f64)),
        ("max_new", Json::Num(cb_max_new as f64)),
        ("stagger_ms", Json::Num(stagger.as_secs_f64() * 1e3)),
        ("cb_tokens_per_sec", Json::Num(cb_tps)),
        ("sequential_tokens_per_sec", Json::Num(seq_tps)),
        ("speedup", Json::Num(cb_speedup)),
        ("p50_e2e_ms", Json::Num(e2e_stats.p50 * 1e3)),
        ("p95_e2e_ms", Json::Num(e2e_stats.p95 * 1e3)),
        ("p50_queue_wait_ms", Json::Num(qw_stats.p50 * 1e3)),
        ("p95_queue_wait_ms", Json::Num(qw_stats.p95 * 1e3)),
        ("mean_ttft_ms", Json::Num(cb_stats.mean_ttft_secs() * 1e3)),
        (
            "router",
            Json::obj(vec![
                ("requests", Json::Num(rt_requests as f64)),
                ("clients", Json::Num(rt_clients as f64)),
                ("prompt_len", Json::Num(rt_plen as f64)),
                ("max_new", Json::Num(rt_max_new as f64)),
                ("replicas_1_tok_s", Json::Num(rt_tps_1)),
                ("replicas_3_tok_s", Json::Num(rt_tps_3)),
                ("speedup", Json::Num(rt_tps_3 / rt_tps_1.max(1e-9))),
            ]),
        ),
    ]);
    println!("BENCH {}", cb_json.to_string());
    if !fast() {
        json::write_file(std::path::Path::new("BENCH_serving_cb.json"), &cb_json).unwrap();
    }
    report.push(("serving_cb", cb_json));

    // ---- 9. serving: slot-batched decode GEMM vs per-slot GEMV -----
    // One decode step of the slot-batched serving path: every busy
    // slot's row through a single class-pinned GEMM, against the
    // retired per-slot formulation (one single-row call per busy slot,
    // each re-packing the shared weight panel). Both run the same
    // wrapper keyed on the slot capacity, so the bits are identical —
    // this measures the packing/blocking amortization the batched path
    // buys. CI's bench gate enforces the direction at >= 4 busy slots
    // (scripts/bench_gate.py, section `serving_batched_decode`).
    let bd_slots = 32usize;
    let (bd_d, bd_n) = if fast() { (256usize, 768usize) } else { (512, 1536) };
    let bd_iters = if fast() { 3 } else { 8 };
    let bd_exec = Executor::new(1);
    println!(
        "## Serving slot-batched decode (max_slots={bd_slots}, d={bd_d}, n={bd_n}, 1 thread)\n"
    );
    let mut rng = Rng::new(0xBD);
    let bd_a = rng.normal_vec(bd_slots * bd_d, 0.0, 0.1);
    let bd_w = rng.normal_vec(bd_d * bd_n, 0.0, 0.1);
    let mut bd_out = vec![0.0f32; bd_slots * bd_n];
    let mut t = Table::new(&["busy slots", "batched tok/s", "per-slot GEMV tok/s", "speedup"]);
    let mut bd_points = Vec::new();
    for &busy in &[1usize, 4, 16, 32] {
        let st_batched = bench(1, bd_iters, || {
            ops::matmul_acc_serving_batched(
                &bd_exec,
                &bd_a[..busy * bd_d],
                &bd_w,
                &mut bd_out[..busy * bd_n],
                busy,
                bd_d,
                bd_n,
                bd_slots,
            );
            std::hint::black_box(&bd_out);
        });
        let st_gemv = bench(1, bd_iters, || {
            for r in 0..busy {
                ops::matmul_acc_serving_batched(
                    &bd_exec,
                    &bd_a[r * bd_d..(r + 1) * bd_d],
                    &bd_w,
                    &mut bd_out[r * bd_n..(r + 1) * bd_n],
                    1,
                    bd_d,
                    bd_n,
                    bd_slots,
                );
            }
            std::hint::black_box(&bd_out);
        });
        let tps_batched = st_batched.per_sec(busy as f64);
        let tps_gemv = st_gemv.per_sec(busy as f64);
        let speedup = st_gemv.mean / st_batched.mean.max(1e-12);
        t.row(&[
            format!("{busy}"),
            format!("{tps_batched:.0}"),
            format!("{tps_gemv:.0}"),
            format!("{speedup:.2}x"),
        ]);
        bd_points.push(Json::obj(vec![
            ("busy", Json::Num(busy as f64)),
            ("batched_tok_s", Json::Num(tps_batched)),
            ("gemv_tok_s", Json::Num(tps_gemv)),
            ("speedup", Json::Num(speedup)),
        ]));
    }
    println!("{}", t.render());
    println!("(per-slot GEMV re-packs the weight panel once per busy slot; batched packs once)\n");
    let bd_json = Json::obj(vec![
        ("bench", Json::Str("serving_batched_decode".into())),
        ("kernel", Json::Str(format!("{:?}", gemm::active_kernel()))),
        ("max_slots", Json::Num(bd_slots as f64)),
        ("d", Json::Num(bd_d as f64)),
        ("n", Json::Num(bd_n as f64)),
        ("points", Json::Arr(bd_points)),
    ]);
    println!("BENCH {}", bd_json.to_string());
    if !fast() {
        json::write_file(std::path::Path::new("BENCH_serving_batched.json"), &bd_json).unwrap();
    }
    report.push(("serving_batched_decode", bd_json));

    // ---- 10. serving: session state cache — turn-2 TTFT cached vs cold
    // A follow-up turn that restores its parked recurrent state prefills
    // only the new tokens, so its TTFT stays ~flat in conversation
    // depth; a cold replay re-ingests the whole transcript and grows
    // linearly. Greedy outputs are asserted bit-identical between the
    // two paths. CI's bench gate enforces cached < cold at depth >= 1024
    // plus bounded flatness (scripts/bench_gate.py, section
    // `serving_state_cache`).
    let sc_depths: &[usize] = if fast() { &[256, 1024] } else { &[256, 1024, 4096] };
    let sc_iters = if fast() { 2 } else { 4 };
    let sc_max_new = 8usize;
    let sc_new_tokens = 16usize;
    println!("## Serving session state cache: turn-2 TTFT, cached resume vs cold replay\n");
    let mut t = Table::new(&["depth", "cached TTFT", "cold TTFT", "speedup"]);
    let mut sc_points = Vec::new();
    for &depth in sc_depths {
        let mut rng = Rng::new(0x5C00 + depth as u64);
        let t1: Vec<i32> = (0..depth).map(|_| rng.below(vocab as u64) as i32).collect();
        let extra: Vec<i32> =
            (0..sc_new_tokens).map(|_| rng.below(vocab as u64) as i32).collect();
        let sc_cfg =
            ServerConfig { state_cache_bytes: 64 << 20, ..ServerConfig::default() };
        let mut cached_ttft = f64::INFINITY;
        let mut cold_ttft = f64::INFINITY;
        let mut cached_tokens = Vec::new();
        let mut cold_tokens = Vec::new();
        for _ in 0..sc_iters {
            // Turn 1 parks its state; turn 2 restores and prefills only
            // the tail. A fresh server per iteration keeps the cache
            // lookup identical every time (take() consumes the entry).
            let mut server = Server::with_config(&session, 7, sc_cfg.clone()).unwrap();
            server
                .submit(GenRequest {
                    id: 1,
                    prompt: t1.clone(),
                    max_new: sc_max_new,
                    temperature: 0.0,
                    deadline: None,
                    session_id: Some("bench".into()),
                })
                .unwrap();
            let r1 = server.run_to_completion().unwrap().pop().unwrap();
            let mut t2 = t1.clone();
            t2.extend_from_slice(&r1.tokens);
            t2.extend_from_slice(&extra);
            server
                .submit(GenRequest {
                    id: 2,
                    prompt: t2.clone(),
                    max_new: sc_max_new,
                    temperature: 0.0,
                    deadline: None,
                    session_id: Some("bench".into()),
                })
                .unwrap();
            let r2 = server.run_to_completion().unwrap().pop().unwrap();
            assert_eq!(server.stats.cache_hits, 1, "turn 2 must restore from the cache");
            cached_ttft = cached_ttft.min(r2.ttft_secs);
            cached_tokens = r2.tokens;

            let mut cold = Server::new(&session, 7).unwrap();
            cold.submit(GenRequest {
                id: 3,
                prompt: t2,
                max_new: sc_max_new,
                temperature: 0.0,
                deadline: None,
                session_id: None,
            })
            .unwrap();
            let rc = cold.run_to_completion().unwrap().pop().unwrap();
            cold_ttft = cold_ttft.min(rc.ttft_secs);
            cold_tokens = rc.tokens;
        }
        assert_eq!(
            cached_tokens, cold_tokens,
            "cached resume must be bit-identical to cold full replay"
        );
        let speedup = cold_ttft / cached_ttft.max(1e-12);
        t.row(&[
            format!("{depth}"),
            format!("{:.2} ms", cached_ttft * 1e3),
            format!("{:.2} ms", cold_ttft * 1e3),
            format!("{speedup:.2}x"),
        ]);
        sc_points.push(Json::obj(vec![
            ("depth", Json::Num(depth as f64)),
            ("cached_ttft_ms", Json::Num(cached_ttft * 1e3)),
            ("cold_ttft_ms", Json::Num(cold_ttft * 1e3)),
            ("speedup", Json::Num(speedup)),
        ]));
    }
    println!("{}", t.render());
    println!("(cached resume prefills only the new tokens; outputs bit-identical to replay)\n");
    let sc_json = Json::obj(vec![
        ("bench", Json::Str("serving_state_cache".into())),
        ("kernel", Json::Str(format!("{:?}", gemm::active_kernel()))),
        ("family", Json::Str("lm_tiny_efla".into())),
        ("threads", Json::Num(session.threads() as f64)),
        ("max_new", Json::Num(sc_max_new as f64)),
        ("new_tokens_per_turn", Json::Num(sc_new_tokens as f64)),
        ("points", Json::Arr(sc_points)),
    ]);
    println!("BENCH {}", sc_json.to_string());
    if !fast() {
        json::write_file(std::path::Path::new("BENCH_serving_state_cache.json"), &sc_json)
            .unwrap();
    }
    report.push(("serving_state_cache", sc_json));

    // ---- 11. serving: session affinity — turn-2 TTFT by landing spot
    // What the router's session-affine scheduling buys at the replica
    // level: an *affine* turn 2 lands on the replica holding the parked
    // state (cache hit, prefill only the tail); a *session-blind* pick
    // lands on a replica that never saw the session (cold
    // full-transcript prefill); a *failover* turn 2 first migrates the
    // state through the `/v1/state/{session}` wire form
    // (`CachedState::to_wire`/`from_wire`) into a fresh replica and then
    // resumes there. All three paths are asserted bit-identical. CI's
    // bench gate enforces affine < blind at depth >= 1024
    // (scripts/bench_gate.py, section `serving_affinity`).
    let af_depths: &[usize] = if fast() { &[256, 1024] } else { &[256, 1024, 4096] };
    let af_iters = if fast() { 2 } else { 4 };
    let af_max_new = 8usize;
    let af_new_tokens = 16usize;
    println!("## Serving session affinity: turn-2 TTFT, affine vs blind vs failover\n");
    let mut t =
        Table::new(&["depth", "affine TTFT", "blind TTFT", "failover TTFT", "blind/affine"]);
    let mut af_points = Vec::new();
    for &depth in af_depths {
        let mut rng = Rng::new(0xAF00 + depth as u64);
        let t1: Vec<i32> = (0..depth).map(|_| rng.below(vocab as u64) as i32).collect();
        let extra: Vec<i32> =
            (0..af_new_tokens).map(|_| rng.below(vocab as u64) as i32).collect();
        let af_cfg =
            ServerConfig { state_cache_bytes: 64 << 20, ..ServerConfig::default() };
        let mut affine_ttft = f64::INFINITY;
        let mut blind_ttft = f64::INFINITY;
        let mut failover_ttft = f64::INFINITY;
        let mut affine_tokens = Vec::new();
        let mut blind_tokens = Vec::new();
        let mut failover_tokens = Vec::new();
        for _ in 0..af_iters {
            // Turn 1 on replica A parks the session state.
            let mut a = Server::with_config(&session, 7, af_cfg.clone()).unwrap();
            a.submit(GenRequest {
                id: 1,
                prompt: t1.clone(),
                max_new: af_max_new,
                temperature: 0.0,
                deadline: None,
                session_id: Some("bench".into()),
            })
            .unwrap();
            let r1 = a.run_to_completion().unwrap().pop().unwrap();
            let mut t2 = t1.clone();
            t2.extend_from_slice(&r1.tokens);
            t2.extend_from_slice(&extra);

            // Failover: A's parked state crosses to a fresh replica B
            // through the wire form, then turn 2 resumes on B.
            let parked =
                a.state_cache().lock().unwrap().take_any("bench").expect("turn 1 parked");
            let wire = parked.to_wire();
            let mut b = Server::with_config(&session, 7, af_cfg.clone()).unwrap();
            b.state_cache()
                .lock()
                .unwrap()
                .insert("bench", CachedState::from_wire(&wire).unwrap());
            b.submit(GenRequest {
                id: 2,
                prompt: t2.clone(),
                max_new: af_max_new,
                temperature: 0.0,
                deadline: None,
                session_id: Some("bench".into()),
            })
            .unwrap();
            let rf = b.run_to_completion().unwrap().pop().unwrap();
            assert_eq!(b.stats.cache_hits, 1, "failover turn 2 must hit the migrated state");
            failover_ttft = failover_ttft.min(rf.ttft_secs);
            failover_tokens = rf.tokens;

            // Affine: turn 2 lands back on A. Re-import the identical
            // wire payload (take_any consumed the original above —
            // migration copies the serialized entry verbatim).
            a.state_cache()
                .lock()
                .unwrap()
                .insert("bench", CachedState::from_wire(&wire).unwrap());
            a.submit(GenRequest {
                id: 3,
                prompt: t2.clone(),
                max_new: af_max_new,
                temperature: 0.0,
                deadline: None,
                session_id: Some("bench".into()),
            })
            .unwrap();
            let r2 = a.run_to_completion().unwrap().pop().unwrap();
            assert_eq!(a.stats.cache_hits, 1, "affine turn 2 must hit the cache");
            affine_ttft = affine_ttft.min(r2.ttft_secs);
            affine_tokens = r2.tokens;

            // Session-blind: turn 2 on a replica that never saw the
            // session — a cold full-transcript prefill.
            let mut c = Server::with_config(&session, 7, af_cfg.clone()).unwrap();
            c.submit(GenRequest {
                id: 4,
                prompt: t2,
                max_new: af_max_new,
                temperature: 0.0,
                deadline: None,
                session_id: Some("bench".into()),
            })
            .unwrap();
            let rb = c.run_to_completion().unwrap().pop().unwrap();
            assert_eq!(c.stats.cache_hits, 0, "blind turn 2 must miss the cache");
            blind_ttft = blind_ttft.min(rb.ttft_secs);
            blind_tokens = rb.tokens;
        }
        assert_eq!(affine_tokens, blind_tokens, "affine must match the cold replay");
        assert_eq!(failover_tokens, blind_tokens, "migrated resume must match the cold replay");
        let speedup = blind_ttft / affine_ttft.max(1e-12);
        t.row(&[
            format!("{depth}"),
            format!("{:.2} ms", affine_ttft * 1e3),
            format!("{:.2} ms", blind_ttft * 1e3),
            format!("{:.2} ms", failover_ttft * 1e3),
            format!("{speedup:.2}x"),
        ]);
        af_points.push(Json::obj(vec![
            ("depth", Json::Num(depth as f64)),
            ("affine_ttft_ms", Json::Num(affine_ttft * 1e3)),
            ("blind_ttft_ms", Json::Num(blind_ttft * 1e3)),
            ("failover_ttft_ms", Json::Num(failover_ttft * 1e3)),
            ("speedup", Json::Num(speedup)),
        ]));
    }
    println!("{}", t.render());
    println!("(failover = wire-form state migration + resume; all paths bit-identical)\n");
    let af_json = Json::obj(vec![
        ("bench", Json::Str("serving_affinity".into())),
        ("kernel", Json::Str(format!("{:?}", gemm::active_kernel()))),
        ("family", Json::Str("lm_tiny_efla".into())),
        ("threads", Json::Num(session.threads() as f64)),
        ("max_new", Json::Num(af_max_new as f64)),
        ("new_tokens_per_turn", Json::Num(af_new_tokens as f64)),
        ("points", Json::Arr(af_points)),
    ]);
    println!("BENCH {}", af_json.to_string());
    if !fast() {
        json::write_file(std::path::Path::new("BENCH_serving_affinity.json"), &af_json)
            .unwrap();
    }
    report.push(("serving_affinity", af_json));

    let out = Json::Obj(
        report.into_iter().map(|(k, v)| (k.to_string(), v)).collect(),
    );
    let path = std::path::Path::new("bench_results");
    std::fs::create_dir_all(path).ok();
    json::write_file(&path.join("kernel_throughput.json"), &out).unwrap();
    if fast() {
        println!("fast mode: root-level BENCH_*.json left untouched");
    } else {
        println!("json: BENCH_kernel_gemm.json");
        println!("json: BENCH_forward_threads.json");
        println!("json: BENCH_serving.json");
        println!("json: BENCH_serving_cb.json");
        println!("json: BENCH_serving_batched.json");
        println!("json: BENCH_serving_state_cache.json");
        println!("json: BENCH_serving_affinity.json");
    }
    println!("json: bench_results/kernel_throughput.json");
}
